//! Single-lane (single-PE) bit-serial operation semantics.
//!
//! These routines execute one PE's view of an operand-level instruction
//! directly on the column-striped register file, bit by bit, exactly as
//! the FA/S datapath of Fig 1(b) would. They are the *reference semantics*:
//! the block/row executor and the packed engine are both tested against
//! them lane-for-lane.

use crate::bram::ColumnMemory;
use crate::isa::{booth_recode, fa_s, AluOp};

/// Execute `dst[0..w] = op(x[0..w], y[0..w])` on one lane, bit-serially.
///
/// Returns the final carry (the borrow-complement for SUB), which hardware
/// leaves in the PE's carry register.
pub fn serial_alu(
    mem: &mut ColumnMemory,
    lane: usize,
    op: AluOp,
    dst: usize,
    x: usize,
    y: usize,
    w: u32,
) -> bool {
    let mut carry = op.initial_carry();
    for b in 0..w as usize {
        let xb = mem.get(x + b, lane);
        let yb = mem.get(y + b, lane);
        let r = fa_s(op, xb, yb, carry);
        mem.set(dst + b, lane, r.sum);
        carry = r.carry;
    }
    carry
}

/// Execute `dst[0..len] = op(x[0..len], stream)` where the Y operand
/// arrives as a bit stream (the `A-OP-NET` OpMux configuration): the
/// network receiver's Y input is the transmitted operand.
pub fn serial_alu_stream(
    mem: &mut ColumnMemory,
    lane: usize,
    op: AluOp,
    dst: usize,
    x: usize,
    ybits: &[bool],
) -> bool {
    let mut carry = op.initial_carry();
    for (b, &yb) in ybits.iter().enumerate() {
        let xb = mem.get(x + b, lane);
        let r = fa_s(op, xb, yb, carry);
        mem.set(dst + b, lane, r.sum);
        carry = r.carry;
    }
    carry
}

/// Read `w` bits of a lane as a bool stream (the transmitter side of the
/// network path), sign-extended to `out_len` bits.
pub fn read_stream(
    mem: &ColumnMemory,
    lane: usize,
    base: usize,
    w: u32,
    out_len: usize,
) -> Vec<bool> {
    let mut bits = Vec::with_capacity(out_len);
    let sign = mem.get(base + w as usize - 1, lane);
    for b in 0..out_len {
        if b < w as usize {
            bits.push(mem.get(base + b, lane));
        } else {
            bits.push(sign);
        }
    }
    bits
}

/// Booth radix-2 multiply on one lane:
/// `dst[0..2w] = mand[0..w] * mier[0..w]` (signed × signed, exact).
///
/// Implements the algorithm exactly as the overlay executes it
/// (paper §III-B, Table II):
///
/// 1. the accumulator is cleared through the `0-OP-B` OpMux configuration;
/// 2. for each multiplier bit `i` (LSB first) the Op-Encoder recodes
///    `{mier[i], mier[i-1]}` into ADD / SUB / NOP;
/// 3. an active step serially adds (or subtracts) the sign-extended
///    multiplicand into accumulator bits `i..2w`.
///
/// Returns the number of *active* (non-NOP) Booth steps, which the
/// NOP-skipping latency model consumes.
pub fn booth_mult(
    mem: &mut ColumnMemory,
    lane: usize,
    dst: usize,
    mand: usize,
    mier: usize,
    w: u32,
) -> u32 {
    let w = w as usize;
    // Step 1: 0-OP-B initialization — clear the 2w-bit accumulator lane.
    for b in 0..2 * w {
        mem.set(dst + b, lane, false);
    }
    let mand_sign = mem.get(mand + w - 1, lane);
    let mut active = 0;
    let mut prev = false;
    for i in 0..w {
        let cur = mem.get(mier + i, lane);
        let op = booth_recode(cur, prev);
        prev = cur;
        if op == AluOp::Cpx {
            continue; // NOP step
        }
        active += 1;
        // Serial add/sub of the sign-extended multiplicand into acc[i..2w].
        let mut carry = op.initial_carry();
        for b in 0..(2 * w - i) {
            let xb = mem.get(dst + i + b, lane);
            let yb = if b < w { mem.get(mand + b, lane) } else { mand_sign };
            let r = fa_s(op, xb, yb, carry);
            mem.set(dst + i + b, lane, r.sum);
            carry = r.carry;
        }
    }
    active
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn mem_with(vals: &[(usize, i64)], base_w: u32) -> ColumnMemory {
        let mut m = ColumnMemory::new(1024, 4);
        for &(base, v) in vals {
            m.set_lane_value(0, base, base_w, v);
        }
        m
    }

    #[test]
    fn serial_add_exhaustive_i6() {
        let mut m = ColumnMemory::new(64, 1);
        for x in -32i64..32 {
            for y in -32i64..32 {
                m.set_lane_value(0, 0, 6, x);
                m.set_lane_value(0, 8, 6, y);
                serial_alu(&mut m, 0, AluOp::Add, 16, 0, 8, 6);
                let expect = crate::bits::sign_extend(((x + y) as u64) & 0x3F, 6);
                assert_eq!(m.lane_value(0, 16, 6), expect, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn serial_sub_exhaustive_i6() {
        let mut m = ColumnMemory::new(64, 1);
        for x in -32i64..32 {
            for y in -32i64..32 {
                m.set_lane_value(0, 0, 6, x);
                m.set_lane_value(0, 8, 6, y);
                serial_alu(&mut m, 0, AluOp::Sub, 16, 0, 8, 6);
                let expect = crate::bits::sign_extend(((x - y) as u64) & 0x3F, 6);
                assert_eq!(m.lane_value(0, 16, 6), expect, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn copy_ops() {
        let mut m = mem_with(&[(0, -17), (8, 23)], 8);
        serial_alu(&mut m, 0, AluOp::Cpx, 16, 0, 8, 8);
        assert_eq!(m.lane_value(0, 16, 8), -17);
        serial_alu(&mut m, 0, AluOp::Cpy, 24, 0, 8, 8);
        assert_eq!(m.lane_value(0, 24, 8), 23);
    }

    #[test]
    fn booth_mult_exhaustive_i8() {
        // Every third x against every signed 8-bit y — the core
        // correctness theorem of the multiplier.
        let mut m = ColumnMemory::new(64, 1);
        for x in (-128i64..=127).step_by(3) {
            for y in -128i64..=127 {
                m.set_lane_value(0, 0, 8, x);
                m.set_lane_value(0, 8, 8, y);
                booth_mult(&mut m, 0, 16, 0, 8, 8);
                assert_eq!(m.lane_value(0, 16, 16), x * y, "x={x} y={y}");
            }
        }
    }

    #[test]
    fn booth_mult_wide_random() {
        let mut rng = Xoshiro256::seeded(0xB007);
        let mut m = ColumnMemory::new(256, 1);
        for &w in &[4u32, 12, 16, 24] {
            for _ in 0..200 {
                let lo = -(1i64 << (w - 1));
                let hi = (1i64 << (w - 1)) - 1;
                let x = rng.range_i64(lo, hi);
                let y = rng.range_i64(lo, hi);
                m.set_lane_value(0, 0, w, x);
                m.set_lane_value(0, 64, w, y);
                booth_mult(&mut m, 0, 128, 0, 64, w);
                assert_eq!(m.lane_value(0, 128, 2 * w), x * y, "w={w} x={x} y={y}");
            }
        }
    }

    #[test]
    fn booth_active_steps_match_recoder() {
        let mut m = ColumnMemory::new(64, 1);
        for y in -128i64..=127 {
            m.set_lane_value(0, 0, 8, 7);
            m.set_lane_value(0, 8, 8, y);
            let active = booth_mult(&mut m, 0, 16, 0, 8, 8);
            assert_eq!(active, crate::isa::booth_active_steps(y, 8), "y={y}");
        }
    }

    #[test]
    fn stream_ops_match_regular() {
        let mut m = mem_with(&[(0, 100), (8, -42)], 8);
        let ybits = read_stream(&m, 0, 8, 8, 8);
        serial_alu_stream(&mut m, 0, AluOp::Add, 16, 0, &ybits);
        assert_eq!(m.lane_value(0, 16, 8), 58);
        // Sign extension in the stream.
        let ybits = read_stream(&m, 0, 8, 8, 12);
        assert!(ybits[8] && ybits[11], "sign bits extended");
    }

    #[test]
    fn mult_does_not_clobber_sources() {
        let mut m = mem_with(&[(0, -77), (8, 99)], 8);
        booth_mult(&mut m, 0, 16, 0, 8, 8);
        assert_eq!(m.lane_value(0, 0, 8), -77);
        assert_eq!(m.lane_value(0, 8, 8), 99);
        assert_eq!(m.lane_value(0, 16, 16), -77 * 99);
    }
}
