//! Deterministic pseudo-random number generators.
//!
//! SplitMix64 (Steele et al.) is used for seeding; xoshiro256++ (Blackman &
//! Vigna) is the workhorse generator. Both are tiny, fast, and — crucially
//! for the test suite — fully deterministic across platforms.

/// SplitMix64: a 64-bit mixer used to expand a single seed into a stream.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from an arbitrary seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256++ PRNG. Not cryptographic; excellent for simulation workloads.
#[derive(Debug, Clone)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed the generator; the four state words are expanded with SplitMix64
    /// so that any `u64` seed (including 0) yields a valid non-zero state.
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform value in `[0, bound)`. Uses Lemire's multiply-shift reduction
    /// with rejection to remove modulo bias.
    #[inline]
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound || lo >= bound.wrapping_neg() % bound {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform `usize` in `[lo, hi)` (half-open). Panics if `lo >= hi`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo < hi, "range({lo}, {hi})");
        lo + self.next_below((hi - lo) as u64) as usize
    }

    /// Uniform `i64` in `[lo, hi]` (inclusive), supporting negative bounds.
    #[inline]
    pub fn range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        lo.wrapping_add(self.next_below(span) as i64)
    }

    /// Random boolean.
    #[inline]
    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Uniform `f64` in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fill a slice with signed values of the given bit width (two's
    /// complement range `[-2^(n-1), 2^(n-1)-1]`), as the corner-turning
    /// front end would receive from a host.
    pub fn fill_signed(&mut self, out: &mut [i64], bits: u32) {
        assert!((1..=63).contains(&bits));
        let lo = -(1i64 << (bits - 1));
        let hi = (1i64 << (bits - 1)) - 1;
        for v in out.iter_mut() {
            *v = self.range_i64(lo, hi);
        }
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn splitmix_known_vector() {
        // Reference values for seed 1234567 (from the public-domain C code).
        let mut sm = SplitMix64::new(1234567);
        let v = sm.next_u64();
        assert_eq!(v, 6457827717110365317);
    }

    #[test]
    fn xoshiro_bounds() {
        let mut rng = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let v = rng.next_below(37);
            assert!(v < 37);
        }
        for _ in 0..10_000 {
            let v = rng.range(5, 9);
            assert!((5..9).contains(&v));
        }
    }

    #[test]
    fn xoshiro_signed_fill_respects_width() {
        let mut rng = Xoshiro256::seeded(99);
        let mut buf = vec![0i64; 4096];
        for bits in [1u32, 2, 4, 8, 16, 32] {
            rng.fill_signed(&mut buf, bits);
            let lo = -(1i64 << (bits - 1));
            let hi = (1i64 << (bits - 1)) - 1;
            assert!(buf.iter().all(|&v| v >= lo && v <= hi), "bits={bits}");
        }
    }

    #[test]
    fn xoshiro_f64_in_unit_interval() {
        let mut rng = Xoshiro256::seeded(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Xoshiro256::seeded(11);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn range_i64_inclusive_hits_ends() {
        let mut rng = Xoshiro256::seeded(5);
        let (mut saw_lo, mut saw_hi) = (false, false);
        for _ in 0..10_000 {
            let v = rng.range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            saw_lo |= v == -2;
            saw_hi |= v == 2;
        }
        assert!(saw_lo && saw_hi);
    }
}
