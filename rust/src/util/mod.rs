//! Small self-contained utilities: deterministic PRNG, statistics, and
//! formatting helpers.
//!
//! The build environment is network-isolated and the vendored crate set does
//! not include `rand`, so we carry a tiny, well-tested PRNG of our own
//! (SplitMix64 seeding a xoshiro256++), which is all the simulator and the
//! property-testing mini-framework need.

mod rng;
mod stats;

pub use rng::{SplitMix64, Xoshiro256};
pub use stats::{OnlineStats, Percentiles};

/// Integer ceiling division.
#[inline]
pub const fn div_ceil(a: usize, b: usize) -> usize {
    (a + b - 1) / b
}

/// `ceil(log2(x))` for `x >= 1`. `ceil_log2(1) == 0`.
#[inline]
pub const fn ceil_log2(x: usize) -> u32 {
    assert!(x >= 1);
    (usize::BITS - (x - 1).leading_zeros()) * ((x > 1) as u32)
}

/// Exact `log2` of a power of two; panics otherwise.
#[inline]
pub fn exact_log2(x: usize) -> u32 {
    assert!(x.is_power_of_two(), "exact_log2 of non-power-of-two {x}");
    x.trailing_zeros()
}

/// Format a count with thousands separators (`12_345 -> "12,345"`).
pub fn group_thousands(n: u64) -> String {
    let s = n.to_string();
    let bytes = s.as_bytes();
    let mut out = String::with_capacity(s.len() + s.len() / 3);
    for (i, b) in bytes.iter().enumerate() {
        if i > 0 && (bytes.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(*b as char);
    }
    out
}

/// Format a frequency in Hz in engineering units (e.g. `737 MHz`).
pub fn fmt_freq(hz: f64) -> String {
    if hz >= 1e9 {
        format!("{:.2} GHz", hz / 1e9)
    } else if hz >= 1e6 {
        format!("{:.0} MHz", hz / 1e6)
    } else if hz >= 1e3 {
        format!("{:.0} kHz", hz / 1e3)
    } else {
        format!("{hz:.0} Hz")
    }
}

/// Format an operations-per-second rate (e.g. `1.25 TMAC/s`).
pub fn fmt_rate(per_sec: f64, unit: &str) -> String {
    let (v, prefix) = if per_sec >= 1e12 {
        (per_sec / 1e12, "T")
    } else if per_sec >= 1e9 {
        (per_sec / 1e9, "G")
    } else if per_sec >= 1e6 {
        (per_sec / 1e6, "M")
    } else if per_sec >= 1e3 {
        (per_sec / 1e3, "k")
    } else {
        (per_sec, "")
    };
    format!("{v:.2} {prefix}{unit}/s")
}

/// Format a duration given in nanoseconds with a sensible unit.
pub fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.2} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.2} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.2} us", ns / 1e3)
    } else {
        format!("{ns:.1} ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn div_ceil_basic() {
        assert_eq!(div_ceil(0, 4), 0);
        assert_eq!(div_ceil(1, 4), 1);
        assert_eq!(div_ceil(4, 4), 1);
        assert_eq!(div_ceil(5, 4), 2);
        assert_eq!(div_ceil(128, 16), 8);
    }

    #[test]
    fn ceil_log2_basic() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(1024), 10);
        assert_eq!(ceil_log2(1025), 11);
    }

    #[test]
    fn exact_log2_powers() {
        for p in 0..20 {
            assert_eq!(exact_log2(1usize << p), p);
        }
    }

    #[test]
    #[should_panic]
    fn exact_log2_rejects_non_pow2() {
        exact_log2(12);
    }

    #[test]
    fn thousands() {
        assert_eq!(group_thousands(0), "0");
        assert_eq!(group_thousands(999), "999");
        assert_eq!(group_thousands(1000), "1,000");
        assert_eq!(group_thousands(1234567), "1,234,567");
    }

    #[test]
    fn freq_formatting() {
        assert_eq!(fmt_freq(737e6), "737 MHz");
        assert_eq!(fmt_freq(1.5e9), "1.50 GHz");
    }

    #[test]
    fn rate_formatting() {
        assert_eq!(fmt_rate(1.25e12, "MAC"), "1.25 TMAC/s");
        assert_eq!(fmt_rate(5.0e9, "op"), "5.00 Gop/s");
    }
}
