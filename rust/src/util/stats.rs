//! Streaming and batch statistics used by the metrics layer and the bench
//! harness (no `criterion` in the vendored crate set — we keep our own).

/// Welford online mean/variance accumulator plus min/max.
#[derive(Debug, Clone, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Add one observation.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let delta = x - self.mean;
        self.mean += delta / self.n as f64;
        self.m2 += delta * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Arithmetic mean (0 if empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (0 if fewer than two observations).
    pub fn stddev(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    /// Smallest observation (`inf` if empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest observation (`-inf` if empty).
    pub fn max(&self) -> f64 {
        self.max
    }

    /// Merge another accumulator into this one (parallel Welford).
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = self.n + other.n;
        let delta = other.mean - self.mean;
        let mean = self.mean + delta * other.n as f64 / n as f64;
        let m2 = self.m2
            + other.m2
            + delta * delta * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.mean = mean;
        self.m2 = m2;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Batch percentile computation over a recorded sample set.
#[derive(Debug, Clone, Default)]
pub struct Percentiles {
    samples: Vec<f64>,
    sorted: bool,
}

impl Percentiles {
    /// Empty recorder.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record an observation.
    pub fn push(&mut self, x: f64) {
        self.samples.push(x);
        self.sorted = false;
    }

    /// Number of recorded observations.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    /// True if no observations recorded.
    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// The `q`-th quantile (`0.0..=1.0`) by linear interpolation.
    /// Returns `None` when empty.
    pub fn quantile(&mut self, q: f64) -> Option<f64> {
        if self.samples.is_empty() {
            return None;
        }
        if !self.sorted {
            self.samples
                .sort_by(|a, b| a.partial_cmp(b).expect("NaN sample"));
            self.sorted = true;
        }
        let q = q.clamp(0.0, 1.0);
        let pos = q * (self.samples.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        let frac = pos - lo as f64;
        Some(self.samples[lo] * (1.0 - frac) + self.samples[hi] * frac)
    }

    /// Median shortcut.
    pub fn median(&mut self) -> Option<f64> {
        self.quantile(0.5)
    }

    /// p99 shortcut.
    pub fn p99(&mut self) -> Option<f64> {
        self.quantile(0.99)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn online_mean_and_stddev() {
        let mut s = OnlineStats::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        // Sample stddev of this classic set is ~2.138.
        assert!((s.stddev() - 2.13809).abs() < 1e-4);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn online_merge_matches_sequential() {
        let data: Vec<f64> = (0..1000).map(|i| (i as f64).sin() * 10.0).collect();
        let mut all = OnlineStats::new();
        for &x in &data {
            all.push(x);
        }
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        for &x in &data[..317] {
            a.push(x);
        }
        for &x in &data[317..] {
            b.push(x);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert!((a.mean() - all.mean()).abs() < 1e-9);
        assert!((a.stddev() - all.stddev()).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let mut p = Percentiles::new();
        for i in 1..=100 {
            p.push(i as f64);
        }
        assert!((p.median().unwrap() - 50.5).abs() < 1e-12);
        assert!((p.quantile(0.0).unwrap() - 1.0).abs() < 1e-12);
        assert!((p.quantile(1.0).unwrap() - 100.0).abs() < 1e-12);
        assert!(p.p99().unwrap() > 98.0);
    }

    #[test]
    fn empty_cases() {
        let s = OnlineStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.stddev(), 0.0);
        let mut p = Percentiles::new();
        assert!(p.median().is_none());
    }
}
