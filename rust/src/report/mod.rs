//! Paper-style table and figure-series renderers.
//!
//! Every bench target prints its artifact through these helpers so the
//! output carries both the **paper** value and the **measured/modeled**
//! value side by side — EXPERIMENTS.md is assembled from these outputs.

pub mod paper;

use std::fmt::Write as _;

/// A simple fixed-width text table.
#[derive(Debug, Clone, Default)]
pub struct TextTable {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl TextTable {
    /// New table with a title and column headers.
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Self {
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            title: title.into(),
        }
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience for &str cells.
    pub fn row_str(&mut self, cells: &[&str]) -> &mut Self {
        let owned: Vec<String> = cells.iter().map(|s| s.to_string()).collect();
        self.row(&owned)
    }

    /// Render with column alignment.
    pub fn render(&self) -> String {
        let ncols = self.headers.len();
        let mut widths = vec![0usize; ncols];
        for (i, h) in self.headers.iter().enumerate() {
            widths[i] = widths[i].max(h.len());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "## {}", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(s, " {:<width$} |", c, width = widths[i]);
            }
            let _ = writeln!(out, "{s}");
        };
        line(&mut out, &self.headers);
        let mut sep = String::from("|");
        for w in &widths {
            let _ = write!(sep, "{}|", "-".repeat(w + 2));
        }
        let _ = writeln!(out, "{sep}");
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }
}

/// Format a fraction as a percentage with one decimal.
pub fn pct(f: f64) -> String {
    format!("{:.1}%", f * 100.0)
}

/// Format `measured (paper P)` pairs for the comparison columns.
pub fn vs_paper(measured: impl std::fmt::Display, paper: impl std::fmt::Display) -> String {
    format!("{measured} (paper {paper})")
}

/// Relative error helper for EXPERIMENTS.md annotations.
pub fn rel_err(measured: f64, paper: f64) -> f64 {
    if paper == 0.0 {
        0.0
    } else {
        (measured - paper).abs() / paper.abs()
    }
}

/// An ASCII bar chart for figure-series (one bar per point).
pub fn bar_chart(title: &str, series: &[(String, f64)], unit: &str) -> String {
    let max = series.iter().map(|(_, v)| *v).fold(f64::MIN, f64::max);
    let label_w = series.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = format!("## {title}\n");
    for (label, v) in series {
        let bar_len = if max > 0.0 { (v / max * 48.0).round() as usize } else { 0 };
        let _ = writeln!(
            out,
            "{:<label_w$}  {:>10.3} {unit}  {}",
            label,
            v,
            "#".repeat(bar_len.max(1)),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = TextTable::new("Demo", &["a", "metric"]);
        t.row_str(&["x", "1"]).row_str(&["longer", "22"]);
        let s = t.render();
        assert!(s.contains("## Demo"));
        assert!(s.contains("| a      | metric |"));
        assert!(s.contains("| longer | 22     |"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = TextTable::new("t", &["a", "b"]);
        t.row_str(&["only-one"]);
    }

    #[test]
    fn helpers() {
        assert_eq!(pct(0.9375), "93.8%");
        assert_eq!(vs_paper(259, 259), "259 (paper 259)");
        assert!((rel_err(1.05, 1.0) - 0.05).abs() < 1e-12);
        let chart = bar_chart("F", &[("a".into(), 1.0), ("b".into(), 2.0)], "T");
        assert!(chart.contains("####"));
    }
}
