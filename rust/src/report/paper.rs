//! Generators for every table and figure in the paper's evaluation,
//! with paper-reported values alongside the model/simulator outputs.
//! Shared by the CLI (`picaso table4` …) and the bench targets.

use super::{bar_chart, pct, TextTable};
use crate::analytic::{AccumModel, DesignPoint, MacLatencyModel, ThroughputModel};
use crate::arch::{ArchKind, CustomDesign, PipelineConfig};
use crate::device::{table7_devices, Device};
use crate::synth::{ImplModel, OverlayDesign};

/// The designs plotted in Figs 5–7.
fn fig_designs() -> Vec<ArchKind> {
    vec![
        ArchKind::Custom(CustomDesign::Ccb),
        ArchKind::Custom(CustomDesign::CoMeFaD),
        ArchKind::Custom(CustomDesign::CoMeFaA),
        ArchKind::Custom(CustomDesign::DMod),
        ArchKind::Custom(CustomDesign::AMod),
        ArchKind::PICASO_F,
    ]
}

/// Table IV: tile resources and Fmax for all five overlay configurations
/// on both study devices.
pub fn table4() -> String {
    let mut out = String::new();
    for dev_id in ["V7", "U55"] {
        let dev = Device::by_id(dev_id).unwrap();
        let mut t = TextTable::new(
            format!("Table IV — 4x4 PE-block tiles on {dev_id} ({})", dev.part),
            &["design", "LUT (tile/block)", "FF (tile/block)", "Slice (tile/block)", "Max-Freq"],
        );
        for design in OverlayDesign::TABLE4 {
            let r = ImplModel::tile_report(design, dev);
            t.row(&[
                design.name(),
                format!("{}/{}", r.tile_lut, r.block.lut),
                format!("{}/{}", r.tile_ff, r.block.ff),
                format!("{}/{}", r.tile_slice, r.block.slice),
                crate::util::fmt_freq(r.fmax_hz),
            ]);
        }
        out.push_str(&t.render());
        out.push('\n');
    }
    out.push_str(
        "paper: Full-Pipe 540/737 MHz = BRAM Fmax; 2.25x/1.67x over benchmark; \
         >=2x utilization improvement in all configs\n",
    );
    out
}

/// Table V: cycle latencies, analytic + cycle-accurate cross-check.
pub fn table5() -> String {
    let mut t = TextTable::new(
        "Table V — cycle latency of operations (q=128, N=32)",
        &["operation", "SPAR-2 [26]", "PiCaSO-F", "paper"],
    );
    let n = 32;
    t.row(&[
        "ADD/SUB".into(),
        format!("{}", AccumModel::add_cycles(n)),
        format!("{}", AccumModel::add_cycles(n)),
        "2N = 64".into(),
    ]);
    t.row(&[
        "MULT".into(),
        format!("{}", AccumModel::mult_cycles(n)),
        format!("{}", AccumModel::mult_cycles(n)),
        "2N^2+2N = 2112".into(),
    ]);
    let (spar2, picaso) = AccumModel::table5(128, n);
    t.row(&[
        "Accumulation".into(),
        format!("{spar2}"),
        format!("{picaso}"),
        "4512 / 259 (17.4x)".into(),
    ]);
    let mut out = t.render();
    out.push_str(&format!(
        "measured improvement: {:.1}x (paper: 17x)\n",
        spar2 as f64 / picaso as f64
    ));
    out
}

/// Table VI: largest overlay arrays on the study devices.
pub fn table6() -> String {
    let mut out = String::new();
    let paper: &[(&str, &str, &str, f64, f64, f64, f64, f64)] = &[
        ("V7", "Benchmark [26]", "24K", 0.746, 0.160, 0.738, 0.321, 0.860),
        ("V7", "PiCaSO-F", "33K", 0.325, 0.380, 0.999, 0.021, 0.764),
        ("U55", "Benchmark [26]", "63K", 0.416, 0.097, 0.984, 0.195, 0.634),
        ("U55", "PiCaSO-F", "64K", 0.148, 0.173, 1.000, 0.008, 0.320),
    ];
    let mut t = TextTable::new(
        "Table VI — largest overlay arrays (model vs paper)",
        &["device", "design", "Max-Size", "LUT", "FF", "BRAM", "Uniq.Ctrl", "Slice", "limiter"],
    );
    for (dev_id, name, psize, plut, pff, pbram, pctrl, pslice) in paper {
        let dev = Device::by_id(dev_id).unwrap();
        let design = if name.starts_with("Bench") {
            OverlayDesign::Benchmark
        } else {
            OverlayDesign::PiCaSO(PipelineConfig::FullPipe)
        };
        let r = ImplModel::max_array(design, dev);
        t.row(&[
            dev_id.to_string(),
            name.to_string(),
            format!("{}K (paper {psize})", r.pes_k()),
            format!("{} ({})", pct(r.lut_frac), pct(*plut)),
            format!("{} ({})", pct(r.ff_frac), pct(*pff)),
            format!("{} ({})", pct(r.bram_frac), pct(*pbram)),
            format!("{} ({})", pct(r.ctrl_frac), pct(*pctrl)),
            format!("{} ({})", pct(r.slice_frac), pct(*pslice)),
            r.limiter.as_str().into(),
        ]);
    }
    let mut s = t.render();
    s.push_str("cells: model (paper). Benchmark on V7 is control-set limited; PiCaSO is BRAM limited everywhere.\n");
    out.push_str(&s);
    out
}

/// Table VII: the device list with derived columns.
pub fn table7() -> String {
    let mut t = TextTable::new(
        "Table VII — representative Virtex-7 and UltraScale+ devices",
        &["device", "tech", "BRAM#", "LUT:BRAM ratio", "Max PE#", "ID"],
    );
    for d in table7_devices() {
        t.row(&[
            d.part.into(),
            d.family.tag().into(),
            format!("{}", d.bram36),
            format!("{}", d.lut_bram_ratio()),
            format!("{}K", d.max_pes_k()),
            d.id.into(),
        ]);
    }
    t.render()
}

/// Table VIII: the design-comparison matrix.
pub fn table8() -> String {
    let pts = DesignPoint::table8();
    let mut t = TextTable::new(
        "Table VIII — comparison with customized BRAM PIM architectures",
        &["row", "CCB", "CoMeFa-D", "CoMeFa-A", "PiCaSO-F", "A-Mod"],
    );
    let cells = |f: &dyn Fn(&DesignPoint) -> String| -> Vec<String> {
        pts.iter().map(|p| f(p)).collect()
    };
    let mut row = |label: &str, f: &dyn Fn(&DesignPoint) -> String| {
        let mut v = vec![label.to_string()];
        v.extend(cells(f));
        t.row(&v);
    };
    row("Architecture", &|p| p.architecture().into());
    row("Clock Overhead", &|p| pct(p.clock_overhead()));
    row("Parallel MACs", &|p| p.parallel_macs().to_string());
    row("Mult Latency (N=8)", &|p| p.mult_latency_n8().to_string());
    row("Accum Latency (q=16,N=8)", &|p| p.accum_latency().to_string());
    row("Support Booth's", &|p| p.booth().as_str().into());
    row("Mem. Efficiency", &|p| p.memory_class().into());
    let mut s = t.render();
    s.push_str(
        "paper row values: Mult 86/86/86/144/86; Accum 80/80/80/48/40; MACs 144/144/144/36/144\n",
    );
    s
}

/// Fig 4: the scalability sweep across Table VII devices.
pub fn fig4() -> String {
    let points = ImplModel::scalability(&table7_devices());
    let mut t = TextTable::new(
        "Fig 4 — PiCaSO-F scalability across Virtex-7 / UltraScale+ devices",
        &["device", "PEs", "BRAM", "LUT", "FF", "Slice", "clock"],
    );
    for p in &points {
        t.row(&[
            p.device.id.into(),
            crate::util::group_thousands(p.report.pes as u64),
            pct(p.report.bram_frac),
            pct(p.report.lut_frac),
            pct(p.report.ff_frac),
            pct(p.report.slice_frac),
            crate::util::fmt_freq(p.clock_hz),
        ]);
    }
    let mut s = t.render();
    s.push_str(
        "paper: 100% BRAM on every device; ~40% LUT/FF on V7-a, ~5% on US-c — \
         linear scaling with BRAM capacity\n",
    );
    s
}

/// Fig 5: relative MAC latency w.r.t. PiCaSO.
pub fn fig5() -> String {
    let m = MacLatencyModel::u55();
    let mut out = String::new();
    for n in [4u32, 8, 16] {
        let series: Vec<(String, f64)> = fig_designs()
            .into_iter()
            .map(|k| (k.name(), m.relative(k, n)))
            .collect();
        out.push_str(&bar_chart(
            &format!("Fig 5 — relative MAC latency w.r.t. PiCaSO, {n}-bit"),
            &series,
            "x",
        ));
        out.push('\n');
    }
    out.push_str(
        "paper: PiCaSO 1.72x-2.56x faster than CoMeFa-A; CoMeFa-D wins only at 16-bit\n",
    );
    out
}

/// Fig 6: peak MAC throughput on the U55.
pub fn fig6() -> String {
    let t = ThroughputModel::u55();
    let mut out = String::new();
    for n in [4u32, 8, 16] {
        let series: Vec<(String, f64)> = fig_designs()
            .into_iter()
            .map(|k| (k.name(), t.tmacs(k, n)))
            .collect();
        out.push_str(&bar_chart(
            &format!("Fig 6 — peak MAC throughput on Alveo U55, {n}-bit"),
            &series,
            "TMAC/s",
        ));
        let frac = t.tmacs(ArchKind::PICASO_F, n)
            / t.tmacs(ArchKind::Custom(CustomDesign::CoMeFaA), n);
        out.push_str(&format!("PiCaSO/CoMeFa-A = {:.1}%\n\n", frac * 100.0));
    }
    out.push_str("paper: PiCaSO achieves 75%-80% of CoMeFa-A; Mods gain 5%-18%\n");
    out
}

/// Fig 7: BRAM memory utilization efficiency.
pub fn fig7() -> String {
    let designs = [
        ("CCB", ArchKind::Custom(CustomDesign::Ccb)),
        ("CoMeFa", ArchKind::Custom(CustomDesign::CoMeFaA)),
        ("CoMeFa-Mod", ArchKind::Custom(CustomDesign::AMod)),
        ("PiCaSO", ArchKind::PICASO_F),
    ];
    let mut t = TextTable::new(
        "Fig 7 — BRAM memory utilization efficiency",
        &["precision", "CCB", "CoMeFa", "CoMeFa-Mod", "PiCaSO"],
    );
    for n in [4u32, 8, 16, 32] {
        let mut row = vec![format!("{n}-bit")];
        for (_, k) in designs {
            row.push(pct(k.memory_efficiency(n)));
        }
        t.row(&row);
    }
    let mut s = t.render();
    s.push_str("paper @16-bit: CCB 50%, CoMeFa 68.8%, PiCaSO 93.8%; Mod +6.2pp over CoMeFa\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_artifacts_render() {
        for (name, s) in [
            ("table4", table4()),
            ("table5", table5()),
            ("table6", table6()),
            ("table7", table7()),
            ("table8", table8()),
            ("fig4", fig4()),
            ("fig5", fig5()),
            ("fig6", fig6()),
            ("fig7", fig7()),
        ] {
            assert!(s.len() > 100, "{name} too short:\n{s}");
        }
    }

    #[test]
    fn table5_headline_in_output() {
        let s = table5();
        assert!(s.contains("4512"));
        assert!(s.contains("259"));
        assert!(s.contains("17.4x"));
    }

    #[test]
    fn fig7_paper_points_in_output() {
        let s = fig7();
        assert!(s.contains("50.0%"), "{s}");
        assert!(s.contains("93.8%"), "{s}");
        assert!(s.contains("68.8%"), "{s}");
        assert!(s.contains("75.0%"), "{s}");
    }

    #[test]
    fn table6_reports_limits() {
        let s = table6();
        assert!(s.contains("control sets"));
        assert!(s.contains("BRAM"));
    }
}
