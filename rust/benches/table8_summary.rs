//! Bench: paper Table VIII (design-comparison matrix).
#[path = "harness.rs"]
mod harness;

use picaso::analytic::DesignPoint;
use picaso::report::paper;

fn main() {
    harness::section("Table VIII — comparison with custom BRAM PIM architectures");
    print!("{}", paper::table8());
    harness::section("timing");
    harness::bench("table8_matrix", 10, || {
        for p in DesignPoint::table8() {
            std::hint::black_box((p.mult_latency_n8(), p.accum_latency(), p.memory_class()));
        }
    });
}
