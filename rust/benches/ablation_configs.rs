//! Ablation bench: the design choices the paper motivates, isolated.
//!
//! 1. **Pipeline configuration** (§III-E): same cycle counts, different
//!    achievable clock → effective MAC latency per config per device.
//! 2. **Booth NOP skipping** (§V): expected multiply latency with/without
//!    the skip, measured on the simulator over random operands.
//! 3. **Fold pattern** (Fig 2a vs 2b): both reduce in log depth; the
//!    adjacent pattern additionally supports pooling windows.
#[path = "harness.rs"]
mod harness;

use picaso::analytic::design_clock_hz;
use picaso::arch::{ArchKind, PipelineConfig};
use picaso::array::{ArrayGeometry, PimArray, RunStats};
use picaso::compiler::{BUF_A, BUF_B};
use picaso::device::Device;
use picaso::isa::{BufId, FoldPattern, Instruction, Microcode, RfAddr};
use picaso::util::Xoshiro256;

fn main() {
    harness::section("ablation 1 — pipeline config: effective MAC latency (N=8, q=16)");
    let u55 = Device::by_id("U55").unwrap();
    let v7 = Device::by_id("V7").unwrap();
    for cfg in PipelineConfig::ALL {
        let kind = ArchKind::Overlay(cfg);
        let cycles = kind.cycles().mult(8) + kind.cycles().accumulate(16, 8);
        for dev in [v7, u55] {
            let f = design_clock_hz(kind, dev);
            println!(
                "  {:12} on {:3}: {} cycles @ {} = {}",
                cfg.name(),
                dev.id,
                cycles,
                picaso::util::fmt_freq(f),
                picaso::util::fmt_ns(cycles as f64 / f * 1e9)
            );
        }
    }

    harness::section("ablation 2 — Booth NOP skipping (N=8, 64 lanes)");
    // The paper's 'potential 50%' reduction (§V) needs the *sequencer* to
    // skip a step, which lock-step SIMD only can when every lane recodes
    // NOP. Two workloads isolate this:
    //  (a) per-lane random multipliers  -> some step is active somewhere,
    //      no skipping despite ~50% per-lane NOPs;
    //  (b) broadcast multiplier (weight-stationary MV product) -> all
    //      lanes share the recode and ~half the steps vanish.
    let geom = ArrayGeometry::new(1, 4);
    let mut rng = Xoshiro256::seeded(0xAB1A);
    let mut a = vec![0i64; 64];
    rng.fill_signed(&mut a, 8);
    let mut b_lane = vec![0i64; 64];
    rng.fill_signed(&mut b_lane, 8);
    let b_bcast = vec![0b0110_0110i64; 64]; // 4 of 8 Booth steps active
    for (label, b, skip) in [
        ("per-lane, no skip   ", &b_lane, false),
        ("per-lane, skip      ", &b_lane, true),
        ("broadcast, skip     ", &b_bcast, true),
    ] {
        let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
        arr.set_booth_skip(skip);
        arr.set_buffer(BUF_A, a.clone());
        arr.set_buffer(BUF_B, b.clone());
        let mut mc = Microcode::new("m", 8);
        mc.push(Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) });
        mc.push(Instruction::Load { dst: RfAddr(8), width: 8, buf: BufId(1) });
        mc.push(Instruction::Mult { dst: RfAddr(16), mand: RfAddr(0), mier: RfAddr(8), width: 8 });
        let stats = arr.execute(&mc).unwrap();
        println!(
            "  {label}: {:3} mult cycles (worst case 2N^2+2N = 144)",
            stats.breakdown.mult
        );
    }

    harness::section("ablation 3 — fold pattern (both reduce 16 lanes to lane 0)");
    for pattern in [FoldPattern::Halving, FoldPattern::Adjacent] {
        let mut arr = PimArray::new(ArrayGeometry::new(1, 1), PipelineConfig::FullPipe);
        arr.set_buffer(BUF_A, (1..=16).collect());
        let mut mc = Microcode::new("fold", 16);
        mc.push(Instruction::Load { dst: RfAddr(0), width: 16, buf: BufId(0) });
        for level in 1..=4 {
            mc.push(Instruction::Fold { pattern, level, dst: RfAddr(0), width: 16 });
        }
        arr.execute(&mc).unwrap();
        let sum = arr.row_result(0, RfAddr(0), 16);
        assert_eq!(sum, 136);
        println!("  {pattern:?}: row sum = {sum} (correct), 4 levels");
    }

    harness::section("timing — full MAC group across configs");
    for cfg in [PipelineConfig::SingleCycle, PipelineConfig::FullPipe] {
        let mut arr = PimArray::new(geom, cfg);
        arr.set_buffer(BUF_A, a.clone());
        arr.set_buffer(BUF_B, b_lane.clone());
        let mut mc = Microcode::new("mac", 8);
        mc.push(Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) });
        mc.push(Instruction::Load { dst: RfAddr(8), width: 8, buf: BufId(1) });
        mc.push(Instruction::Mult { dst: RfAddr(16), mand: RfAddr(0), mier: RfAddr(8), width: 8 });
        mc.push(Instruction::Accumulate { dst: RfAddr(16), width: 16 });
        harness::bench(&format!("mac_group_{}", cfg.name()), 5, || {
            let mut s = RunStats::default();
            for i in &mc.instrs {
                arr.step(*i, &mut s).unwrap();
            }
            std::hint::black_box(s.cycles);
        });
    }
}
