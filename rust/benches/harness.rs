//! Minimal bench harness (the vendored crate set has no criterion).
//!
//! Each bench target is a `harness = false` binary that (a) regenerates
//! its paper artifact through `picaso::report::paper` and (b) times the
//! underlying model/simulator with warmup + repeated samples, reporting
//! mean / stddev / min. Output is designed to be `tee`'d into
//! bench_output.txt and pasted into EXPERIMENTS.md.

use std::time::Instant;

/// One timed result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Mean wall time per iteration (ns).
    pub mean_ns: f64,
    /// Sample standard deviation (ns).
    pub stddev_ns: f64,
    /// Fastest sample (ns).
    pub min_ns: f64,
    /// Iterations per sample.
    pub iters: u64,
}

impl BenchResult {
    /// Render one line.
    pub fn line(&self) -> String {
        format!(
            "bench {:40} {:>12.0} ns/iter (+/- {:.0}, min {:.0}, {} iters/sample)",
            self.name, self.mean_ns, self.stddev_ns, self.min_ns, self.iters
        )
    }
}

/// Time `f`, auto-calibrating the iteration count so each sample runs
/// ≥ ~20 ms, then taking `samples` samples.
pub fn bench<F: FnMut()>(name: &str, samples: usize, mut f: F) -> BenchResult {
    // Warmup + calibration.
    let mut iters = 1u64;
    loop {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        let dt = t0.elapsed();
        if dt.as_millis() >= 20 || iters >= 1 << 24 {
            break;
        }
        let scale = (0.02 / dt.as_secs_f64().max(1e-9)).ceil() as u64;
        iters = (iters * scale.clamp(2, 100)).min(1 << 24);
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        times.push(t0.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>()
        / (times.len().max(2) - 1) as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let r = BenchResult {
        name: name.to_string(),
        mean_ns: mean,
        stddev_ns: var.sqrt(),
        min_ns: min,
        iters,
    };
    println!("{}", r.line());
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}
