//! Bench: the simulator hot path (EXPERIMENTS.md §Perf).
//!
//! Measures simulated PE-cycles per wall-second for the three dominant
//! operations — Booth multiply, fold+hop accumulation, and a full GEMM —
//! on the scalar reference engine and the packed (bit-sliced) engine.
#[path = "harness.rs"]
mod harness;

use picaso::array::{ArrayGeometry, PackedEngine, PimArray};
use picaso::bram::ColumnMemory;
use picaso::compiler::{execute_gemm, GemmShape, PimCompiler};
use picaso::isa::{Instruction, Microcode, RfAddr, BufId};
use picaso::prelude::PipelineConfig;
use picaso::util::Xoshiro256;

fn main() {
    let mut rng = Xoshiro256::seeded(0xBEEF);

    harness::section("scalar engine — Booth mult, 1024 lanes, N=8");
    let lanes = 1024;
    let mut mem = ColumnMemory::new(256, lanes);
    let mut a = vec![0i64; lanes];
    let mut b = vec![0i64; lanes];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    for (l, (&x, &y)) in a.iter().zip(&b).enumerate() {
        mem.set_lane_value(l, 0, 8, x);
        mem.set_lane_value(l, 8, 8, y);
    }
    let mut scalar_mem = mem.clone();
    let r1 = harness::bench("scalar_booth_mult_1024xN8", 10, || {
        for lane in 0..lanes {
            std::hint::black_box(picaso::pe::booth_mult(&mut scalar_mem, lane, 16, 0, 8, 8));
        }
    });

    harness::section("packed engine — same workload");
    let mut packed_mem = mem.clone();
    let r2 = harness::bench("packed_booth_mult_1024xN8", 10, || {
        std::hint::black_box(PackedEngine::mult(&mut packed_mem, 16, 0, 8, 8));
    });
    // Equivalence.
    for lane in 0..lanes {
        assert_eq!(
            scalar_mem.lane_value(lane, 16, 16),
            packed_mem.lane_value(lane, 16, 16),
            "packed engine must match scalar, lane {lane}"
        );
    }
    // The paper-model cycle count for this op: 144 cycles x 1024 lanes.
    let pe_cycles = 144.0 * lanes as f64;
    println!(
        "scalar: {} PE-cycles/s   packed: {} PE-cycles/s   speedup {:.1}x",
        picaso::util::fmt_rate(pe_cycles / (r1.mean_ns / 1e9), "cyc"),
        picaso::util::fmt_rate(pe_cycles / (r2.mean_ns / 1e9), "cyc"),
        r1.mean_ns / r2.mean_ns
    );

    harness::section("end-to-end GEMM on the array simulator");
    let geom = ArrayGeometry::new(8, 4);
    let shape = GemmShape { m: 16, k: 64, n: 16 };
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    let mut ga = vec![0i64; shape.m * shape.k];
    let mut gb = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut ga, 8);
    rng.fill_signed(&mut gb, 8);
    let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
    let mut cycles = 0u64;
    let r3 = harness::bench("gemm_16x64x16_full_pipe", 10, || {
        let (c, stats) = execute_gemm(&mut arr, &plan, &ga, &gb).unwrap();
        std::hint::black_box(c);
        cycles = stats.cycles;
    });
    println!(
        "gemm: {} pim-cycles per run -> {} sim-cycles/s",
        cycles,
        picaso::util::fmt_rate(cycles as f64 / (r3.mean_ns / 1e9), "cyc")
    );

    harness::section("accumulate macro (q=128, N=32)");
    let geom2 = ArrayGeometry::new(1, 8);
    let mut arr2 = PimArray::new(geom2, PipelineConfig::FullPipe);
    arr2.set_buffer(BufId(0), (0..128).collect());
    let mut mc = Microcode::new("acc", 32);
    mc.push(Instruction::Load { dst: RfAddr(0), width: 32, buf: BufId(0) });
    arr2.execute(&mc).unwrap();
    harness::bench("accumulate_q128_n32", 10, || {
        let mut s = picaso::array::RunStats::default();
        arr2.step(Instruction::Accumulate { dst: RfAddr(0), width: 32 }, &mut s)
            .unwrap();
        std::hint::black_box(s.cycles);
    });
}
