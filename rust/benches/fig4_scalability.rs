//! Bench: paper Fig 4 (scalability across Table VII devices).
#[path = "harness.rs"]
mod harness;

use picaso::device::table7_devices;
use picaso::report::paper;
use picaso::synth::ImplModel;

fn main() {
    harness::section("Fig 4 — scalability study");
    print!("{}", paper::fig4());
    harness::section("timing");
    let devs = table7_devices();
    harness::bench("scalability_sweep_8_devices", 10, || {
        std::hint::black_box(ImplModel::scalability(&devs));
    });
}
