//! Bench: paper Table V (operation cycle latencies) — analytic forms AND
//! the cycle-accurate simulator executing the same operations, asserting
//! they agree before timing the simulator.
#[path = "harness.rs"]
mod harness;

use picaso::arch::ArchKind;
use picaso::array::{ArrayGeometry, PimArray, RunStats};
use picaso::compiler::{BUF_A, BUF_B};
use picaso::isa::{BufId, Instruction, Microcode, RfAddr};
use picaso::prelude::PipelineConfig;
use picaso::report::paper;
use picaso::util::Xoshiro256;

fn main() {
    harness::section("Table V — cycle latencies (q=128, N=32)");
    print!("{}", paper::table5());

    // Cross-check: simulator charges == analytic forms.
    let geom = ArrayGeometry::new(1, 8); // q = 128
    let mut rng = Xoshiro256::seeded(5);
    let mut a = vec![0i64; 128];
    let mut b = vec![0i64; 128];
    rng.fill_signed(&mut a, 16);
    rng.fill_signed(&mut b, 16);

    let mut picaso = PimArray::new(geom, PipelineConfig::FullPipe);
    picaso.set_buffer(BUF_A, a.clone());
    picaso.set_buffer(BUF_B, b.clone());
    let mut mc = Microcode::new("table5", 32);
    mc.push(Instruction::Load { dst: RfAddr(0), width: 32, buf: BufId(0) });
    mc.push(Instruction::Accumulate { dst: RfAddr(0), width: 32 });
    let stats = picaso.execute(&mc).unwrap();
    assert_eq!(stats.breakdown.accumulate, 259, "simulator must charge Table V");

    let mut spar2 = PimArray::with_kind(geom, ArchKind::Spar2);
    spar2.set_buffer(BUF_A, a.clone());
    let stats2 = spar2.execute(&mc).unwrap();
    assert_eq!(stats2.breakdown.accumulate, 4512, "SPAR-2 must charge Table V");
    println!("simulator cycle charges match analytic forms (259 / 4512)");

    harness::section("timing — cycle-accurate accumulation (q=128, N=32)");
    harness::bench("picaso_accumulate_q128_n32", 10, || {
        let mut s = RunStats::default();
        picaso
            .step(Instruction::Accumulate { dst: RfAddr(0), width: 32 }, &mut s)
            .unwrap();
        std::hint::black_box(s.cycles);
    });
    harness::bench("spar2_news_accumulate_q128_n32", 10, || {
        let mut s = RunStats::default();
        spar2
            .step(Instruction::Accumulate { dst: RfAddr(0), width: 32 }, &mut s)
            .unwrap();
        std::hint::black_box(s.cycles);
    });
}
