//! Bench: regenerate paper Table IV (tile resources & Fmax) and time
//! the virtual-implementation model.
#[path = "harness.rs"]
mod harness;

use picaso::device::Device;
use picaso::report::paper;
use picaso::synth::{ImplModel, OverlayDesign};

fn main() {
    harness::section("Table IV — tiles of 4x4 PE-blocks");
    print!("{}", paper::table4());
    harness::section("timing");
    let v7 = Device::by_id("V7").unwrap();
    let u55 = Device::by_id("U55").unwrap();
    harness::bench("tile_report_all_configs_both_devices", 10, || {
        for design in OverlayDesign::TABLE4 {
            std::hint::black_box(ImplModel::tile_report(design, v7));
            std::hint::black_box(ImplModel::tile_report(design, u55));
        }
    });
}
