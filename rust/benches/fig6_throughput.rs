//! Bench: paper Fig 6 (peak MAC throughput on the U55).
#[path = "harness.rs"]
mod harness;

use picaso::analytic::ThroughputModel;
use picaso::arch::{ArchKind, CustomDesign};
use picaso::report::paper;

fn main() {
    harness::section("Fig 6 — peak MAC throughput on Alveo U55");
    print!("{}", paper::fig6());
    harness::section("timing");
    let t = ThroughputModel::u55();
    let designs = [
        ArchKind::Custom(CustomDesign::Ccb),
        ArchKind::Custom(CustomDesign::CoMeFaD),
        ArchKind::Custom(CustomDesign::CoMeFaA),
        ArchKind::Custom(CustomDesign::AMod),
        ArchKind::Custom(CustomDesign::DMod),
        ArchKind::PICASO_F,
    ];
    harness::bench("throughput_model_all_designs_3_precisions", 10, || {
        for k in designs {
            for n in [4u32, 8, 16] {
                std::hint::black_box(t.tmacs(k, n));
            }
        }
    });
}
