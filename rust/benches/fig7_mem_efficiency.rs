//! Bench: paper Fig 7 (BRAM memory utilization efficiency).
#[path = "harness.rs"]
mod harness;

use picaso::arch::{ArchKind, CustomDesign};
use picaso::bram::RegisterFileBudget;
use picaso::report::paper;

fn main() {
    harness::section("Fig 7 — BRAM memory utilization efficiency");
    print!("{}", paper::fig7());
    // Paper spot values.
    assert!((ArchKind::Custom(CustomDesign::Ccb).memory_efficiency(16) - 0.50).abs() < 1e-9);
    assert!((ArchKind::PICASO_F.memory_efficiency(16) - 0.9375).abs() < 1e-9);
    harness::section("timing");
    harness::bench("budget_model_all_designs", 10, || {
        for n in [4u32, 8, 16, 32] {
            for k in [
                ArchKind::Custom(CustomDesign::Ccb),
                ArchKind::Custom(CustomDesign::CoMeFaA),
                ArchKind::Custom(CustomDesign::AMod),
                ArchKind::PICASO_F,
            ] {
                std::hint::black_box(RegisterFileBudget::for_arch(k, n).efficiency());
            }
        }
    });
}
