//! Bench: paper Fig 5 (relative MAC latency w.r.t. PiCaSO) — analytic
//! series, behavioural cross-check on the custom-tile simulators, and
//! timing of the behavioural models.
#[path = "harness.rs"]
mod harness;

use picaso::analytic::MacLatencyModel;
use picaso::arch::{ArchKind, CustomDesign};
use picaso::custom::CustomTile;
use picaso::report::paper;
use picaso::util::Xoshiro256;

fn main() {
    harness::section("Fig 5 — relative MAC latency");
    print!("{}", paper::fig5());

    // Behavioural check: tile simulators charge exactly the analytic MAC
    // cycles used in the figure (at accumulate width N).
    let m = MacLatencyModel::u55();
    let mut rng = Xoshiro256::seeded(9);
    let mut a = vec![0i64; 16];
    let mut b = vec![0i64; 16];
    rng.fill_signed(&mut a, 4);
    rng.fill_signed(&mut b, 4);
    for design in CustomDesign::ALL {
        let mut tile = CustomTile::new(design);
        // mac_group accumulates at 2N; the figure pairs Table VIII\'s
        // width-N row — check the mult portion matches either way.
        let (_, stats) = tile.mac_group(&a, &b, 4, 16).unwrap();
        let kind = ArchKind::Custom(design);
        assert_eq!(
            stats.cycles,
            kind.cycles().mult(4) + kind.cycles().accumulate(16, 8),
            "{design:?}"
        );
        let _ = m.relative(kind, 4);
    }
    println!("behavioural tiles agree with analytic cycle charges");

    harness::section("timing — behavioural custom-tile MAC (N=8, q=16)");
    let mut a8 = vec![0i64; 16];
    let mut b8 = vec![0i64; 16];
    rng.fill_signed(&mut a8, 8);
    rng.fill_signed(&mut b8, 8);
    for design in [CustomDesign::CoMeFaA, CustomDesign::AMod] {
        let mut tile = CustomTile::new(design);
        harness::bench(&format!("tile_mac_{}", design.name()), 10, || {
            std::hint::black_box(tile.mac_group(&a8, &b8, 8, 16).unwrap());
        });
    }
}
