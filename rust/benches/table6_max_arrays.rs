//! Bench: paper Table VI (largest placeable arrays) and the placement
//! model search time.
#[path = "harness.rs"]
mod harness;

use picaso::device::Device;
use picaso::prelude::PipelineConfig;
use picaso::report::paper;
use picaso::synth::{ImplModel, OverlayDesign};

fn main() {
    harness::section("Table VI — largest overlay arrays");
    print!("{}", paper::table6());
    harness::section("timing");
    let devs = ["V7", "U55"].map(|d| Device::by_id(d).unwrap());
    harness::bench("max_array_search_both_designs", 10, || {
        for dev in &devs {
            std::hint::black_box(ImplModel::max_array(OverlayDesign::Benchmark, dev));
            std::hint::black_box(ImplModel::max_array(
                OverlayDesign::PiCaSO(PipelineConfig::FullPipe),
                dev,
            ));
        }
    });
}
