//! Convolution workloads end to end: conv layers and `cnn:` models
//! through the serving stack, bit-exact against the scalar direct
//! convolution across overlay/custom/mixed pools, strides/padding, and
//! fixed/tuned tile policies.

use picaso::arch::CustomDesign;
use picaso::compiler::gemm_ref;
use picaso::coordinator::{Coordinator, CoordinatorConfig, RegionSpec};
use picaso::model::{
    CompileOptions, CompiledModel, ExecMode, GraphBuilder, GraphExecutor, TuneMode,
};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use picaso::workload::ConvWorkload;

fn filled(len: usize, width: u32, seed: u64) -> Vec<i64> {
    let mut rng = Xoshiro256::seeded(seed);
    let mut v = vec![0i64; len];
    rng.fill_signed(&mut v, width);
    v
}

fn pools() -> Vec<(&'static str, CoordinatorConfig)> {
    let overlay = RegionSpec { kind: ArchKind::PICASO_F, count: 1 };
    let comefa = RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 };
    vec![
        (
            "overlay",
            CoordinatorConfig {
                workers: 2,
                geom: ArrayGeometry::new(2, 1),
                kind: ArchKind::PICASO_F,
                ..Default::default()
            },
        ),
        (
            "custom",
            CoordinatorConfig {
                workers: 2,
                geom: ArrayGeometry::new(2, 1),
                kind: ArchKind::Custom(CustomDesign::CoMeFaA),
                ..Default::default()
            },
        ),
        (
            "mixed",
            CoordinatorConfig {
                geom: ArrayGeometry::new(2, 1),
                regions: vec![overlay, comefa],
                ..Default::default()
            },
        ),
    ]
}

/// The acceptance matrix: a conv layer served through the stack must
/// reproduce [`ConvWorkload::conv_ref`] bit-exactly on every pool
/// class, across strides/padding/channels, under no tiling, a fixed
/// 2-D grid, and the auto-tuner.
#[test]
fn conv_layers_bit_exact_vs_direct_convolution_across_pools() {
    // (c, h, w, k, r, s, stride, pad): stride-2, ragged taps, deep pad.
    let geoms = [
        (2usize, 5usize, 5usize, 3usize, 3usize, 3usize, 1usize, 0usize),
        (1, 6, 5, 2, 3, 2, 2, 1),
        (2, 5, 5, 2, 3, 3, 1, 2),
    ];
    let items = 2;
    for (name, cfg) in pools() {
        for (gi, (c, h, w, k, r, s, stride, pad)) in geoms.into_iter().enumerate() {
            let cw = ConvWorkload::new(items, c, h, w, k, r, s, stride, pad).unwrap();
            let input = filled(items * cw.input_len_per_item(), 8, 0x100 + gi as u64);
            let filters = filled(k * r * s * c, 8, 0x200 + gi as u64);
            let expect = cw.conv_ref(items, &input, &filters).unwrap();
            let coord = Coordinator::new(cfg.clone()).unwrap();
            for tune in [
                TuneMode::Fixed(TilePolicy::None),
                TuneMode::Fixed(TilePolicy::grid(2, 2)),
                TuneMode::Auto,
            ] {
                let mut b = GraphBuilder::new(cw.input_len_per_item(), 8);
                b.conv2d(cw, filters.clone()).unwrap();
                let graph = b.build().unwrap();
                assert_eq!(
                    graph.forward_ref(&input, items).unwrap(),
                    expect,
                    "the scalar reference is the direct convolution"
                );
                let model = CompiledModel::compile(
                    &coord,
                    graph,
                    CompileOptions { rows_per_request: items, tune, ..Default::default() },
                )
                .unwrap();
                let exec = GraphExecutor::new(&coord, &model);
                let report = exec.infer_batch(&[input.clone()], ExecMode::Pipelined).unwrap();
                assert_eq!(report.outputs[0], expect, "{name} conv {gi} {tune:?}");
                model.close(&coord);
            }
            coord.shutdown();
        }
    }
}

/// Multi-layer `cnn:` models (conv -> conv -> dense head, both hidden
/// activations) verify bit-exact against the scalar reference on every
/// pool, under a fixed column split and the auto-tuner.
#[test]
fn cnn_models_verify_end_to_end_on_every_pool() {
    let specs =
        [("cnn:2@6x6,3@3x3,4", "sign"), ("cnn:1@5x5,2@3x3s2p1,2@2x2,3", "relu")];
    let m = 1;
    for (name, cfg) in pools() {
        let coord = Coordinator::new(cfg).unwrap();
        for (si, (spec, act)) in specs.into_iter().enumerate() {
            for tune in [TuneMode::Fixed(TilePolicy::Fixed(2)), TuneMode::Auto] {
                let graph = picaso::cli::build_cnn(spec, 8, act, 0x5EED + si as u64).unwrap();
                let inputs: Vec<Vec<i64>> =
                    (0..3).map(|r| filled(graph.input_dim(), 8, 0x300 + r)).collect();
                let expects: Vec<Vec<i64>> =
                    inputs.iter().map(|a| graph.forward_ref(a, m).unwrap()).collect();
                let model = CompiledModel::compile(
                    &coord,
                    graph,
                    CompileOptions { rows_per_request: m, tune, ..Default::default() },
                )
                .unwrap();
                let exec = GraphExecutor::new(&coord, &model);
                let report = exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
                for (i, (got, want)) in report.outputs.iter().zip(&expects).enumerate() {
                    assert_eq!(got, want, "{name} {spec} {tune:?} request {i}");
                }
                model.close(&coord);
            }
        }
        coord.shutdown();
    }
}

/// A 1x1/stride-1/unpadded conv is exactly the plain `(h·w) x c` by
/// `c x k` GEMM — through the whole serving stack, not just the
/// lowering arithmetic.
#[test]
fn one_by_one_conv_is_a_plain_gemm_through_the_stack() {
    let cw = ConvWorkload::new(1, 3, 4, 4, 5, 1, 1, 1, 0).unwrap();
    let input = filled(cw.input_len_per_item(), 8, 0x11);
    let filters = filled(5 * 3, 8, 0x22);
    let shape = cw.gemm_shape();
    assert_eq!(shape, GemmShape { m: 16, k: 3, n: 5 });
    let expect = gemm_ref(shape, &input, &cw.lower_weights(&filters).unwrap());
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let mut b = GraphBuilder::new(cw.input_len_per_item(), 8);
    b.conv2d(cw, filters.clone()).unwrap();
    let graph = b.build().unwrap();
    let model = CompiledModel::compile(
        &coord,
        graph,
        CompileOptions { rows_per_request: 1, ..Default::default() },
    )
    .unwrap();
    let exec = GraphExecutor::new(&coord, &model);
    let report = exec.infer_batch(&[input.clone()], ExecMode::Pipelined).unwrap();
    assert_eq!(report.outputs[0], expect, "conv == plain GEMM");
    assert_eq!(report.outputs[0], cw.conv_ref(1, &input, &filters).unwrap());
    model.close(&coord);
    coord.shutdown();
}
