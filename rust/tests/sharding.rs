//! Sharded scatter–gather GEMM: one logical job split across worker
//! regions, executed concurrently, gathered back bit-exact — across
//! homogeneous and mixed backend pools, even and ragged splits.

use picaso::arch::CustomDesign;
use picaso::compiler::{gemm_ref, split_shape_n, GemmShape, PimCompiler};
use picaso::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, RegionSpec, ShardPolicy,
};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::time::Duration;

fn gemm_job(id: u64, shape: GemmShape, seed: u64) -> (Job, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(shape, &a, &b);
    (Job::new(id, JobKind::Gemm { shape, width: 8, a, b }), expect)
}

fn pool(regions: Vec<RegionSpec>) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        geom: ArrayGeometry::new(2, 1),
        regions,
        ..Default::default()
    })
    .unwrap()
}

/// The fan-out `ShardPolicy::Auto` resolves to: the analytic mapping
/// tuner's grid for this shape on the pool, clamped to the shape the
/// same way the coordinator clamps it.
fn auto_tiles(shape: GemmShape, kinds: &[ArchKind]) -> usize {
    let p = choose_grid(shape, 8, kinds, ArrayGeometry::new(2, 1));
    p.k_tiles.min(shape.k.max(1)) * p.n_tiles.min(shape.n.max(1))
}

/// The acceptance matrix: K ∈ {1, 2, #regions, ragged n % K != 0} on
/// overlay-only, custom-only, and mixed pools — every gathered output
/// bit-exact against the software reference.
#[test]
fn sharded_gemm_bit_exact_across_pools_and_shard_counts() {
    let overlay = RegionSpec { kind: ArchKind::PICASO_F, count: 1 };
    let comefa = RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 };
    let pools: Vec<(&str, Vec<RegionSpec>)> = vec![
        ("overlay-only", vec![RegionSpec { count: 2, ..overlay }]),
        ("custom-only", vec![RegionSpec { count: 2, ..comefa }]),
        ("mixed", vec![overlay, comefa]),
    ];
    let shape = GemmShape { m: 2, k: 20, n: 7 }; // multi-slice, ragged-friendly n
    for (name, regions) in pools {
        let coord = pool(regions);
        let nregions = coord.worker_kinds().len();
        assert_eq!(nregions, 2, "{name}");
        // K = 3 is the ragged case: 7 % 3 != 0.
        for (i, policy) in [
            ShardPolicy::Fixed(1),
            ShardPolicy::Fixed(2),
            ShardPolicy::Fixed(nregions),
            ShardPolicy::Fixed(3),
            ShardPolicy::Auto,
        ]
        .into_iter()
        .enumerate()
        {
            let (job, expect) = gemm_job(i as u64, shape, 0xD00 + i as u64);
            let r = coord.submit_job(job.with_shards(policy)).unwrap().wait();
            assert!(r.error.is_none(), "{name} {policy:?}: {:?}", r.error);
            assert_eq!(r.output, expect, "{name} {policy:?} must match gemm_ref");
            let want_shards = match policy {
                ShardPolicy::Fixed(k) => k.min(shape.n),
                ShardPolicy::Grid { k_tiles, n_tiles } => {
                    k_tiles.min(shape.k) * n_tiles.min(shape.n)
                }
                ShardPolicy::Auto => auto_tiles(shape, coord.worker_kinds()),
                ShardPolicy::None => 1,
            };
            assert_eq!(r.shards, want_shards, "{name} {policy:?}");
            assert!(r.stats.cycles > 0, "{name} {policy:?}: cycles roll up");
        }
        let auto = auto_tiles(shape, coord.worker_kinds()) as u64;
        let snap = coord.metrics_snapshot();
        assert_eq!(
            snap.sharded_jobs,
            3 + u64::from(auto >= 2),
            "{name}: every multi-tile policy scattered"
        );
        assert_eq!(snap.max_shards, 3.max(auto), "{name}");
        coord.shutdown();
    }
}

/// Shard tickets inherit the parent's backend tag: a tagged sharded job
/// in a mixed pool must complete every shard on the tagged class.
#[test]
fn sharded_jobs_respect_backend_tags_in_mixed_pools() {
    let comefa = BackendClass::Custom(CustomDesign::CoMeFaA);
    let coord = pool(vec![
        RegionSpec { kind: ArchKind::PICASO_F, count: 2 },
        RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 2 },
    ]);
    let shape = GemmShape { m: 2, k: 16, n: 6 };
    for (i, tag) in [BackendClass::Overlay, comefa].into_iter().enumerate() {
        let (mut job, expect) = gemm_job(i as u64, shape, 0x7A6 + i as u64);
        job.backend = Some(tag);
        let r = coord.submit_job(job.with_shards(ShardPolicy::Auto)).unwrap().wait();
        assert!(r.error.is_none(), "{tag}: {:?}", r.error);
        assert_eq!(r.output, expect, "{tag}");
        let kinds = coord.compatible_kinds(Some(tag));
        assert_eq!(kinds.len(), 2, "{tag}: the tag halves the pool");
        let want = auto_tiles(shape, &kinds);
        assert!(want >= 2, "{tag}: the tuner splits across the compatible regions");
        assert_eq!(r.shards, want, "auto = the tuner's grid on the 2 compatible regions");
        // Every shard ran on the tagged class, so the merged result
        // keeps the unanimous class.
        assert_eq!(r.backend, Some(tag), "{tag}: a shard landed off-class");
    }
    coord.shutdown();
}

/// The deterministic scaling claim: splitting a GEMM K ways cuts the
/// per-region round count ~K× versus the unsharded plan (exactly K× for
/// even splits). Rounds are plan arithmetic — no timing involved.
#[test]
fn per_region_rounds_drop_k_fold_vs_unsharded() {
    let geom = ArrayGeometry::new(2, 1); // 2 rows per region
    let compiler = PimCompiler::new(geom);
    let shape = GemmShape { m: 4, k: 16, n: 8 }; // 32 outputs => 16 rounds
    let unsharded_rounds = compiler.gemm(shape, 8).unwrap().rounds;
    assert_eq!(unsharded_rounds, 16);
    for k in [2usize, 4] {
        let per_region: Vec<usize> = split_shape_n(shape, k)
            .into_iter()
            .map(|(_, s)| compiler.gemm(s, 8).unwrap().rounds)
            .collect();
        assert_eq!(per_region.len(), k);
        for (region, rounds) in per_region.iter().enumerate() {
            assert_eq!(
                *rounds,
                unsharded_rounds / k,
                "K={k}, region {region}: rounds must drop exactly K-fold"
            );
        }
    }
    // Ragged: per-region rounds still bounded by ceil(unsharded/K) + 1.
    let ragged = GemmShape { m: 4, k: 16, n: 7 }; // 28 outputs => 14 rounds
    let unsharded_rounds = compiler.gemm(ragged, 8).unwrap().rounds;
    let worst = split_shape_n(ragged, 3)
        .into_iter()
        .map(|(_, s)| compiler.gemm(s, 8).unwrap().rounds)
        .max()
        .unwrap();
    assert!(worst <= unsharded_rounds.div_ceil(3) + 1, "worst {worst} of {unsharded_rounds}");
}

/// End-to-end confirmation that the simulated work of the sharded run
/// matches the plan arithmetic: with one region per shard and batching
/// disabled, each region executes its shard's rounds and the rolled-up
/// instruction count equals the unsharded total (even split).
#[test]
fn sharded_instruction_total_matches_unsharded_run() {
    let shape = GemmShape { m: 4, k: 16, n: 8 };
    let run = |shards: ShardPolicy| {
        let coord = Coordinator::new(CoordinatorConfig {
            workers: 4,
            geom: ArrayGeometry::new(2, 1),
            kind: ArchKind::PICASO_F,
            batch: BatchPolicy::disabled(),
            ..Default::default()
        })
        .unwrap();
        let (job, expect) = gemm_job(0, shape, 0xCAFE);
        let r = coord.submit_job(job.with_shards(shards)).unwrap().wait();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.output, expect);
        coord.shutdown();
        r
    };
    let solo = run(ShardPolicy::None);
    let sharded = run(ShardPolicy::Fixed(4));
    assert_eq!(sharded.shards, 4);
    // 8 columns over 4 shards is an even split: the same packed rounds
    // run, just spread across regions — identical total instructions.
    assert_eq!(sharded.stats.instructions, solo.stats.instructions);
    assert_eq!(sharded.stats.cycles, solo.stats.cycles);
}

/// With micro-batching enabled, sibling shards must not coalesce into
/// one batch — that would run the whole scatter serially on a single
/// region. On a one-worker pool every shard therefore dispatches in its
/// own batch, which the merged result reports as `batch_size == 1`.
#[test]
fn sibling_shards_never_serialize_into_one_batch() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_millis(5) },
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 4 };
    let (job, expect) = gemm_job(0, shape, 0x5EA1);
    let r = coord.submit_job(job.with_shards(ShardPolicy::Fixed(4))).unwrap().wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect);
    assert_eq!(r.shards, 4);
    assert_eq!(r.batch_size, 1, "sibling shards coalesced into one batch");
    coord.shutdown();
}

/// Session-backed sharding: pinned-weight inference scatters across
/// regions exactly like ad-hoc GEMMs — the worker slices the session's
/// pre-staged weight table per partition slot — across homogeneous and
/// mixed pools, even and ragged splits, bit-exact against the software
/// reference.
#[test]
fn sharded_session_jobs_bit_exact_across_pools() {
    use picaso::coordinator::SessionId;
    use picaso::util::Xoshiro256;
    let overlay = RegionSpec { kind: ArchKind::PICASO_F, count: 1 };
    let comefa = RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 };
    let pools: Vec<(&str, Vec<RegionSpec>)> = vec![
        ("overlay-only", vec![RegionSpec { count: 2, ..overlay }]),
        ("custom-only", vec![RegionSpec { count: 2, ..comefa }]),
        ("mixed", vec![overlay, comefa]),
    ];
    let shape = GemmShape { m: 2, k: 20, n: 7 }; // multi-slice, ragged n
    for (name, regions) in pools {
        let coord = pool(regions);
        let mut rng = Xoshiro256::seeded(0x5E55_10);
        let mut weights = vec![0i64; shape.k * shape.n];
        rng.fill_signed(&mut weights, 8);
        let sid: SessionId = coord.open_session(shape, 8, weights.clone()).unwrap();
        for (i, policy) in [
            ShardPolicy::Fixed(2),
            ShardPolicy::Fixed(3), // ragged: 7 % 3 != 0
            ShardPolicy::Auto,
            ShardPolicy::Fixed(64), // clamps to n = 7
        ]
        .into_iter()
        .enumerate()
        {
            let mut a = vec![0i64; shape.m * shape.k];
            rng.fill_signed(&mut a, 8);
            let expect = gemm_ref(shape, &a, &weights);
            let job = Job::new(i as u64, JobKind::SessionGemm { session: sid, a: a.into() })
                .with_shards(policy);
            let h = coord.submit_job(job).unwrap();
            let want_shards = match policy {
                ShardPolicy::Fixed(k) => k.min(shape.n),
                ShardPolicy::Grid { k_tiles, n_tiles } => {
                    k_tiles.min(shape.k) * n_tiles.min(shape.n)
                }
                ShardPolicy::Auto => auto_tiles(shape, coord.worker_kinds()),
                ShardPolicy::None => 1,
            };
            assert_eq!(h.shard_count(), want_shards, "{name} {policy:?}");
            let r = h.wait();
            assert!(r.error.is_none(), "{name} {policy:?}: {:?}", r.error);
            assert_eq!(r.output, expect, "{name} {policy:?} must match gemm_ref");
            assert_eq!(r.shards, want_shards, "{name} {policy:?}");
        }
        // Unsharded session inference through the same coordinator still
        // verifies (the whole-session table and its shard views coexist
        // in the worker caches).
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        let expect = gemm_ref(shape, &a, &weights);
        let r = coord.submit_session(100, sid, a).unwrap().wait();
        assert!(r.error.is_none(), "{name}: {:?}", r.error);
        assert_eq!(r.output, expect, "{name}");
        coord.shutdown();
    }
}

/// Sharding survives the legacy submit/drain path for plain GEMMs.
#[test]
fn sharding_composes_with_legacy_submit_path() {
    let mut coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 4 };
    let (job, expect) = gemm_job(0, shape, 0xBEE);
    coord.submit(job.with_shards(ShardPolicy::Fixed(2))).unwrap();
    let rs = coord.drain(1).unwrap();
    assert!(rs[0].error.is_none(), "{:?}", rs[0].error);
    assert_eq!(rs[0].output, expect);
    assert_eq!(rs[0].shards, 2);
    coord.shutdown();
}
