//! Model-graph executor, end to end: multi-layer MLPs bit-exact against
//! the scalar i64 reference across overlay/custom/mixed pools and shard
//! policies, a deterministic cycle-makespan win for pipelined execution
//! over the layer-by-layer baseline, per-layer metrics rollups, and
//! graph/compile validation errors.

use picaso::arch::CustomDesign;
use picaso::backend::BackendClass;
use picaso::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, RegionSpec, ShardPolicy};
use picaso::model::{
    CompileOptions, CompiledModel, ExecMode, GraphBuilder, GraphExecutor, ModelGraph, TuneMode,
};
use picaso::prelude::*;
use picaso::util::Xoshiro256;

/// A 3-layer sign-activated (BNN-flavoured) MLP with ragged feature
/// counts — multi-slice first layer, multi-round everywhere.
fn bnn_mlp(seed: u64) -> ModelGraph {
    picaso::cli::build_mlp(&[20, 7, 5, 3], 8, "sign", seed).expect("valid MLP")
}

fn requests(graph: &ModelGraph, m: usize, count: usize, seed: u64) -> Vec<Vec<i64>> {
    let mut rng = Xoshiro256::seeded(seed);
    (0..count)
        .map(|_| {
            let mut a = vec![0i64; m * graph.input_dim()];
            rng.fill_signed(&mut a, 8);
            a
        })
        .collect()
}

/// The acceptance matrix: a >=3-layer MLP through the graph executor is
/// bit-exact vs the scalar reference on every backend-class pool, under
/// every shard policy, with micro-batching live.
#[test]
fn mlp_bit_exact_across_pools_and_shard_policies() {
    let geom = ArrayGeometry::new(2, 1);
    let pools: Vec<(&str, CoordinatorConfig)> = vec![
        (
            "overlay",
            CoordinatorConfig { workers: 3, geom, ..Default::default() },
        ),
        (
            "custom",
            CoordinatorConfig {
                workers: 2,
                geom,
                kind: ArchKind::Custom(CustomDesign::CoMeFaA),
                ..Default::default()
            },
        ),
        (
            "mixed",
            CoordinatorConfig {
                geom,
                regions: vec![
                    RegionSpec { kind: ArchKind::PICASO_F, count: 1 },
                    RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 },
                ],
                ..Default::default()
            },
        ),
    ];
    for (name, cfg) in pools {
        for shards in [ShardPolicy::None, ShardPolicy::Fixed(2), ShardPolicy::Auto] {
            let coord = Coordinator::new(cfg.clone()).unwrap();
            let graph = bnn_mlp(0x71E + u64::from(shards == ShardPolicy::Auto));
            let m = 2;
            let inputs = requests(&graph, m, 5, 0xFEED);
            let expects: Vec<Vec<i64>> =
                inputs.iter().map(|a| graph.forward_ref(a, m).unwrap()).collect();
            let model = CompiledModel::compile(
                &coord,
                graph,
                CompileOptions {
                    rows_per_request: m,
                    tune: TuneMode::Fixed(shards),
                    ..Default::default()
                },
            )
            .unwrap();
            let exec = GraphExecutor::new(&coord, &model);
            let report = exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
            for (r, (got, want)) in report.outputs.iter().zip(&expects).enumerate() {
                assert_eq!(got, want, "{name} pool, {shards:?}, request {r}");
            }
            assert_eq!(report.per_layer.len(), 3);
            for (l, lr) in report.per_layer.iter().enumerate() {
                assert_eq!(lr.jobs, 5, "{name} {shards:?}: layer {l} served every request");
                assert!(lr.cycles > 0, "{name} {shards:?}: layer {l} charged cycles");
            }
            model.close(&coord);
            coord.shutdown();
        }
    }
}

/// Per-layer backend pins on a mixed pool: each layer dispatches only to
/// its class, outputs stay bit-exact, and the compiled layers report the
/// kinds they were pinned to.
#[test]
fn mixed_pool_pins_layers_to_backend_classes() {
    let comefa = BackendClass::Custom(CustomDesign::CoMeFaA);
    let geom = ArrayGeometry::new(2, 1);
    let coord = Coordinator::new(CoordinatorConfig {
        geom,
        regions: vec![
            RegionSpec { kind: ArchKind::PICASO_F, count: 1 },
            RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 },
        ],
        ..Default::default()
    })
    .unwrap();
    let mut rng = Xoshiro256::seeded(0x9A9);
    let mut w0 = vec![0i64; 8 * 6];
    let mut w1 = vec![0i64; 6 * 4];
    rng.fill_signed(&mut w0, 8);
    rng.fill_signed(&mut w1, 8);
    let mut b = GraphBuilder::new(8, 8);
    let l0 = b.dense(w0, 6).unwrap();
    b.sign(l0).unwrap();
    b.on_backend(l0, BackendClass::Overlay).unwrap();
    let l1 = b.dense(w1, 4).unwrap();
    b.on_backend(l1, comefa).unwrap();
    let graph = b.build().unwrap();
    let inputs = requests(&graph, 1, 4, 0x1CE);
    let expects: Vec<Vec<i64>> =
        inputs.iter().map(|a| graph.forward_ref(a, 1).unwrap()).collect();
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    assert_eq!(BackendClass::of(model.layers()[0].kind), BackendClass::Overlay);
    assert_eq!(BackendClass::of(model.layers()[1].kind), comefa);
    let exec = GraphExecutor::new(&coord, &model);
    let report = exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
    assert_eq!(report.outputs, expects);
    // Both classes actually served layer jobs.
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.per_backend.len(), 2, "{:?}", snap.per_backend);
    coord.shutdown();
}

/// Residual (skip) connections flow through the executor exactly like
/// the reference: the producer layer's post-epilogue output is added at
/// the consumer's gather step.
#[test]
fn residual_graphs_execute_bit_exact() {
    let geom = ArrayGeometry::new(2, 1);
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom,
        ..Default::default()
    })
    .unwrap();
    let mut rng = Xoshiro256::seeded(0xE51D);
    let mut w0 = vec![0i64; 6 * 4];
    let mut w1 = vec![0i64; 4 * 4];
    let mut w2 = vec![0i64; 4 * 2];
    rng.fill_signed(&mut w0, 8);
    rng.fill_signed(&mut w1, 8);
    rng.fill_signed(&mut w2, 8);
    let mut b = GraphBuilder::new(6, 8);
    let l0 = b.dense(w0, 4).unwrap();
    b.sign(l0).unwrap();
    let l1 = b.dense(w1, 4).unwrap();
    b.residual(l1, l0).unwrap();
    // Post-residual values are |dot| + 1 <= 4·127 + 1: shift back into
    // 8-bit range for the final layer.
    b.shift(l1, 3).unwrap();
    let l2 = b.dense(w2, 2).unwrap();
    b.bias(l2, vec![5, -5]).unwrap();
    let graph = b.build().unwrap();
    assert_eq!(graph.output_layer(), l2);
    let inputs = requests(&graph, 1, 6, 0xD1CE);
    let expects: Vec<Vec<i64>> =
        inputs.iter().map(|a| graph.forward_ref(a, 1).unwrap()).collect();
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    let exec = GraphExecutor::new(&coord, &model);
    for mode in [ExecMode::Pipelined, ExecMode::LayerBarrier] {
        let report = exec.infer_batch(&inputs, mode).unwrap();
        assert_eq!(report.outputs, expects, "{mode:?}");
    }
    coord.shutdown();
}

/// The headline acceptance: the pipelined executor shows a measured,
/// deterministic cycle-makespan win over sequential layer-by-layer
/// execution of the same batch. With micro-batching disabled every
/// layer job runs solo, so the simulator's per-layer cycle sums are
/// exactly reproducible — both modes measure identical total cycles,
/// and the pipeline's makespan (fill + steady state at the slowest
/// layer) is strictly below the serialized sum.
#[test]
fn pipelined_beats_layer_by_layer_in_cycles_deterministically() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 3,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })
    .unwrap();
    let graph = picaso::cli::build_mlp(&[16, 12, 8, 4], 8, "sign", 0xBEE).unwrap();
    let inputs = requests(&graph, 1, 6, 0xCAFE);
    let expects: Vec<Vec<i64>> =
        inputs.iter().map(|a| graph.forward_ref(a, 1).unwrap()).collect();
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    let exec = GraphExecutor::new(&coord, &model);

    let pipe = exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
    let barrier = exec.infer_batch(&inputs, ExecMode::LayerBarrier).unwrap();
    assert_eq!(pipe.outputs, expects, "pipelined outputs are bit-exact");
    assert_eq!(barrier.outputs, expects, "barrier outputs are bit-exact");

    // Determinism: identical work, identical simulated cycles, however
    // the two modes interleaved it.
    assert_eq!(
        pipe.total_cycles, barrier.total_cycles,
        "solo-job cycle charges must not depend on scheduling"
    );
    for (l, (a, b)) in pipe.per_layer.iter().zip(&barrier.per_layer).enumerate() {
        assert_eq!(a.cycles, b.cycles, "layer {l} cycles are deterministic");
    }

    // The win: fill + steady-state at the slowest layer beats the
    // serialized sum of every layer.
    assert!(
        pipe.pipelined_makespan_cycles < pipe.sequential_makespan_cycles,
        "pipelined {} !< sequential {}",
        pipe.pipelined_makespan_cycles,
        pipe.sequential_makespan_cycles
    );
    assert!(
        pipe.pipeline_speedup() > 1.1,
        "3-layer x 6-request pipeline should win clearly, got {:.3}x",
        pipe.pipeline_speedup()
    );
    // The compile-time estimate (per-layer dry runs) agrees on the win.
    let est = model.pipeline_estimate(inputs.len());
    assert!(est.speedup() > 1.1, "estimate: {:.3}x", est.speedup());
    assert!(est.pipelined_cycles < est.sequential_cycles);
    coord.shutdown();
}

/// Per-layer rollups stream into the shared serving metrics: one lane
/// per layer with jobs/cycles/retries/occupancy, rendered in the
/// snapshot report.
#[test]
fn per_layer_metrics_roll_up_into_the_snapshot() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let graph = bnn_mlp(0x717);
    let inputs = requests(&graph, 1, 4, 0x919);
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    coord.serving_metrics().reset_window();
    let exec = GraphExecutor::new(&coord, &model);
    exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.per_layer.len(), 3);
    for (l, lane) in snap.per_layer.iter().enumerate() {
        assert_eq!(lane.layer, l);
        assert_eq!(lane.jobs, 4, "layer {l}");
        assert!(lane.cycles > 0, "layer {l}");
        assert!(lane.busy_us > 0.0, "layer {l}");
    }
    let text = snap.render();
    assert!(text.contains("layer 0"), "{text}");
    assert!(text.contains("layer 2"), "{text}");
    coord.shutdown();
}

/// Compile- and run-time validation: pins to absent classes fail at
/// compile, zero-row requests fail at compile, un-requantized graphs
/// fail loudly at run time, and inference against a closed model
/// reports the unknown session.
#[test]
fn compile_and_runtime_validation_fail_loudly() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    // Pin to a class this pool does not have.
    let mut b = GraphBuilder::new(4, 8);
    let l0 = b.dense(vec![1; 8], 2).unwrap();
    b.on_backend(l0, BackendClass::Custom(CustomDesign::DMod)).unwrap();
    let err = CompiledModel::compile(&coord, b.build().unwrap(), CompileOptions::default())
        .unwrap_err();
    assert!(err.to_string().contains("no such region"), "{err}");
    // Zero activation rows.
    let graph = bnn_mlp(1);
    let err = CompiledModel::compile(
        &coord,
        graph,
        CompileOptions { rows_per_request: 0, ..Default::default() },
    )
    .unwrap_err();
    assert!(err.to_string().contains("rows_per_request"), "{err}");
    // Un-requantized activations overflow the operand width at run time
    // — the executor and the reference reject identically.
    let mut b = GraphBuilder::new(4, 8);
    b.dense(vec![127; 4], 1).unwrap();
    b.dense(vec![1], 1).unwrap();
    let graph = b.build().unwrap();
    let hot = vec![127i64; 4];
    assert!(graph.forward_ref(&hot, 1).is_err());
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    let exec = GraphExecutor::new(&coord, &model);
    let err = exec.infer_batch(&[hot], ExecMode::Pipelined).unwrap_err();
    assert!(err.to_string().contains("requant"), "{err}");
    // Wrong input size and empty batches.
    assert!(exec.infer_batch(&[vec![0; 3]], ExecMode::Pipelined).is_err());
    let empty = exec.infer_batch(&[], ExecMode::Pipelined).unwrap();
    assert!(empty.outputs.is_empty());
    // Closing the model releases its sessions: later inference reports
    // the unknown session.
    model.close(&coord);
    let err = exec.infer(vec![1, 2, 3, 4]).unwrap_err();
    assert!(err.to_string().contains("not open"), "{err}");
    coord.shutdown();
}

/// A bounded in-flight window serves large batches correctly (requests
/// admitted as earlier ones complete) and single-request convenience
/// inference matches the reference.
#[test]
fn windowed_pipeline_and_single_infer() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let graph = bnn_mlp(0x3B);
    let inputs = requests(&graph, 1, 9, 0x5150);
    let expects: Vec<Vec<i64>> =
        inputs.iter().map(|a| graph.forward_ref(a, 1).unwrap()).collect();
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    let exec = GraphExecutor::new(&coord, &model).with_window(3);
    let report = exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
    assert_eq!(report.outputs, expects);
    assert_eq!(report.request_us.len(), 9);
    assert!(report.request_us.iter().all(|&us| us > 0.0));
    let one = exec.infer(inputs[0].clone()).unwrap();
    assert_eq!(one, expects[0]);
    coord.shutdown();
}
