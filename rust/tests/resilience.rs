//! Resilient job lifecycle, end to end: fault-injected regions are
//! absorbed by failure-domain retry (bit-exact results, bounded
//! attempts), scatter admission is all-or-none under `Reject`, and
//! expired jobs shed at pop time instead of executing.

use picaso::backend::{FaultInjector, FaultPlan};
use picaso::compiler::{gemm_ref, GemmShape};
use picaso::coordinator::{
    BackendHook, Backpressure, BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind,
    RetryPolicy, SchedulerConfig, ShardPolicy, TicketState,
};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A pool whose region `poisoned[i]` fails every execute — the fault
/// domains the retry machinery must route around.
fn chaos_pool(workers: usize, poisoned: &[usize], batch: BatchPolicy) -> Coordinator {
    let poisoned = poisoned.to_vec();
    Coordinator::new(CoordinatorConfig {
        workers,
        geom: ArrayGeometry::new(2, 1),
        batch,
        backend_hook: Some(BackendHook(Arc::new(move |widx, inner| {
            if poisoned.contains(&widx) {
                Box::new(FaultInjector::new(inner, FaultPlan::Poisoned))
            } else {
                inner
            }
        }))),
        ..Default::default()
    })
    .unwrap()
}

fn gemm_job(id: u64, shape: GemmShape, seed: u64) -> (Job, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(shape, &a, &b);
    (Job::new(id, JobKind::Gemm { shape, width: 8, a, b }), expect)
}

// ------------------------------------------------ failure-domain retry

/// The acceptance scenario: with a fault-injecting region in the pool,
/// K-shard scatters — ad-hoc and session-backed — return bit-exact
/// `gemm_ref` output via retry, and the results report the retry counts
/// consumed. Two of three regions are poisoned, so every shard those
/// regions touch *must* travel to the lone healthy domain.
#[test]
fn sharded_jobs_survive_poisoned_regions_bit_exact() {
    let coord = chaos_pool(3, &[0, 1], BatchPolicy::disabled());
    let shape = GemmShape { m: 2, k: 20, n: 6 };
    let mut rng = Xoshiro256::seeded(0xFA117);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone()).unwrap();
    let mut total_retries = 0u32;
    for i in 0..8u64 {
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        // Alternate ad-hoc scatters (with their own weights) and
        // session-backed scatters (pinned weights, sliced per shard).
        let (job, expect) = if i % 2 == 0 {
            gemm_job(i, shape, 0xAB5 + i)
        } else {
            let expect = gemm_ref(shape, &a, &weights);
            (Job::new(i, JobKind::SessionGemm { session: sid, a: a.into() }), expect)
        };
        let r = coord.submit_job(job.with_shards(ShardPolicy::Fixed(3))).unwrap().wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expect, "job {i} must be bit-exact after retry");
        assert_eq!(r.shards, 3, "job {i}");
        total_retries += r.retries;
    }
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.errors, 0, "every injected fault was absorbed");
    assert!(
        total_retries >= 1 && snap.retries >= 1,
        "poisoned regions must have forced retries (JobResult {total_retries}, \
         metrics {})",
        snap.retries
    );
    assert_eq!(
        u64::from(total_retries),
        snap.retries,
        "JobResult retry counts roll up to the metrics counter"
    );
    coord.shutdown();
}

/// An intermittently failing region (every 2nd execute) is also
/// absorbed: unsharded jobs retried onto the healthy region, bit-exact,
/// zero surfaced errors.
#[test]
fn intermittent_faults_retry_to_a_healthy_region() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::disabled(),
        backend_hook: Some(BackendHook(Arc::new(|widx, inner| {
            if widx == 0 {
                Box::new(FaultInjector::new(inner, FaultPlan::EveryNth(2)))
            } else {
                inner
            }
        }))),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let mut handles = Vec::new();
    let mut expects = Vec::new();
    for i in 0..24u64 {
        let (job, expect) = gemm_job(i, shape, 0x1E7 + i);
        handles.push(coord.submit_job(job).unwrap());
        expects.push(expect);
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expects[i], "job {i}");
    }
    assert_eq!(coord.metrics_snapshot().errors, 0);
    coord.shutdown();
}

/// Bounded-attempt exhaustion: when every fault domain is poisoned the
/// job fails — after consuming exactly the domains it had, with the
/// attempt history in the error — instead of retrying forever.
#[test]
fn retry_exhaustion_fails_with_attempt_history() {
    let coord = chaos_pool(2, &[0, 1], BatchPolicy::disabled());
    let shape = GemmShape { m: 1, k: 16, n: 2 };
    let (job, _) = gemm_job(1, shape, 0xDEAD);
    let r = coord
        .submit_job(job.with_retry(RetryPolicy { max_attempts: 5 }))
        .unwrap()
        .wait();
    let err = r.error.as_deref().unwrap_or("");
    assert!(err.contains("injected fault"), "{err}");
    assert!(
        err.contains("gave up after 2 attempts across 2 regions"),
        "attempt history missing: {err}"
    );
    assert_eq!(r.retries, 1, "one retry consumed before domains ran out");

    // Fail-fast policy: one attempt, no retry, no annotation.
    let (job, _) = gemm_job(2, shape, 0xBEEF);
    let r = coord.submit_job(job.with_retry(RetryPolicy::none())).unwrap().wait();
    let err = r.error.as_deref().unwrap_or("");
    assert!(err.contains("injected fault"), "{err}");
    assert!(!err.contains("gave up"), "fail-fast must not retry: {err}");
    assert_eq!(r.retries, 0);
    coord.shutdown();
}

/// A single-region pool cannot retry (no second fault domain): a
/// transient failure surfaces immediately rather than re-queueing onto
/// the same broken region.
#[test]
fn single_region_pool_fails_fast_without_domains() {
    let coord = chaos_pool(1, &[0], BatchPolicy::disabled());
    let shape = GemmShape { m: 1, k: 16, n: 1 };
    let (job, _) = gemm_job(1, shape, 7);
    let t0 = Instant::now();
    let r = coord.submit_job(job).unwrap().wait();
    assert!(r.error.as_deref().unwrap_or("").contains("injected fault"));
    assert_eq!(r.retries, 0);
    assert!(t0.elapsed() < Duration::from_secs(10), "no retry loop");
    coord.shutdown();
}

// ------------------------------------------- scatter-atomic admission

/// Under `Backpressure::Reject` at capacity, a K-shard scatter either
/// fully enters the queue or cleanly rejects — the queue never holds a
/// partial scatter. The worker is parked on an effectively-infinite
/// coalescing window (it pops the head and waits 600s for companions
/// that never come), so the queue state is fully under the test's
/// control with no wall-clock sensitivity; closing the scheduler at the
/// end releases the window and drains everything admitted.
#[test]
fn reject_at_capacity_never_admits_a_partial_scatter() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        scheduler: SchedulerConfig {
            capacity: 4,
            backpressure: Backpressure::Reject,
            ..Default::default()
        },
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_secs(600) },
        ..Default::default()
    })
    .unwrap();
    let head_shape = GemmShape { m: 1, k: 16, n: 1 };
    let filler_shape = GemmShape { m: 1, k: 16, n: 2 };
    let scatter_shape = GemmShape { m: 1, k: 16, n: 4 };
    // Park the worker: it pops the head and coalesces until close; the
    // fillers use a different batch key so they stay queued.
    let (head, head_expect) = gemm_job(0, head_shape, 1);
    let head_h = coord.submit_job(head).unwrap();
    let t0 = Instant::now();
    while coord.scheduler().depth() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(30), "worker never popped the head");
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut filler_handles = Vec::new();
    let mut filler_expects = Vec::new();
    for i in 1..=4u64 {
        let (job, expect) = gemm_job(i, filler_shape, 100 + i);
        filler_handles.push(coord.submit_job(job).unwrap());
        filler_expects.push(expect);
    }
    assert_eq!(coord.scheduler().depth(), 4, "queue exactly at capacity");
    // A 2-shard scatter cannot fit: it must reject with NOTHING queued.
    let (job, _) = gemm_job(9, scatter_shape, 0x9);
    let err = coord
        .submit_job(job.with_shards(ShardPolicy::Fixed(2)))
        .unwrap_err();
    assert!(matches!(err, picaso::Error::Busy(_)), "{err}");
    assert_eq!(
        coord.scheduler().depth(),
        4,
        "a rejected scatter must leave no partial shard in the queue"
    );
    // Wider than the queue itself can never fit: config error, still
    // nothing queued.
    let (job, _) = gemm_job(10, GemmShape { m: 1, k: 16, n: 8 }, 0xA);
    let err = coord
        .submit_job(job.with_shards(ShardPolicy::Fixed(8)))
        .unwrap_err();
    assert!(matches!(err, picaso::Error::Config(_)), "{err}");
    assert_eq!(coord.scheduler().depth(), 4);
    // Close the queue: the worker's coalescing wait ends, the head
    // executes, and the backlog drains before the pool exits.
    coord.shutdown();
    assert_eq!(head_h.wait().output, head_expect);
    for (h, expect) in filler_handles.into_iter().zip(filler_expects) {
        let r = h.wait();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.output, expect);
    }
    // With room, the same scatter is admitted whole and verifies (fresh
    // pool — the parked one was shut down above).
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        scheduler: SchedulerConfig {
            capacity: 4,
            backpressure: Backpressure::Reject,
            ..Default::default()
        },
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })
    .unwrap();
    let (job, expect) = gemm_job(11, scatter_shape, 0xB);
    let r = coord.submit_job(job.with_shards(ShardPolicy::Fixed(2))).unwrap().wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect);
    assert_eq!(r.shards, 2);
    coord.shutdown();
}

// ---------------------------------------------------- region quarantine

/// A dead region leaves the pop rotation after its consecutive-fault
/// threshold: traffic keeps verifying bit-exact on the healthy regions,
/// and the quarantine events are counted and rendered. (ROADMAP PR-4
/// follow-up: quarantining + retry backoff.)
#[test]
fn dead_region_is_quarantined_while_traffic_stays_bit_exact() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::disabled(),
        scheduler: SchedulerConfig {
            quarantine: picaso::coordinator::QuarantinePolicy {
                threshold: 2,
                cooldown: Duration::from_millis(20),
            },
            ..Default::default()
        },
        backend_hook: Some(BackendHook(Arc::new(|widx, inner| {
            if widx == 0 {
                Box::new(FaultInjector::new(inner, FaultPlan::Poisoned))
            } else {
                inner
            }
        }))),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 1, k: 16, n: 2 };
    // Burst-submit so the backlog keeps the poisoned region popping
    // until its fault streak trips the threshold.
    let mut handles = Vec::new();
    let mut expects = Vec::new();
    for i in 0..24u64 {
        let (job, expect) = gemm_job(i, shape, 0x0DD + i);
        handles.push(coord.submit_job(job).unwrap());
        expects.push(expect);
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expects[i], "job {i} bit-exact through the degraded pool");
    }
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.errors, 0, "every fault absorbed");
    assert!(
        snap.quarantines >= 1,
        "a permanently dead region must be quarantined: {snap:?}"
    );
    assert!(snap.render().contains("quarantines="), "{}", snap.render());
    coord.shutdown();
}

// --------------------------------------------------- deadline shedding

/// A job whose deadline expired while queued is dropped at pop time
/// with a `Shed` result — no array invocation, a distinct metrics
/// counter, and no effect on its queue neighbours.
#[test]
fn expired_jobs_shed_at_pop_not_execute() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 1, k: 16, n: 2 };
    // Deadline 0: expired by the time any worker pops it.
    let (job, _) = gemm_job(1, shape, 0x51);
    let shed_h = coord.submit_job(job.with_deadline_us(0.0)).unwrap();
    let (live, live_expect) = gemm_job(2, shape, 0x52);
    let live_h = coord.submit_job(live).unwrap();
    let r = shed_h.wait();
    assert!(r.shed, "expired job must report shed, got {:?}", r.error);
    assert!(r.error.as_deref().unwrap_or("").contains("shed"), "{:?}", r.error);
    assert!(r.output.is_empty(), "shed jobs never execute");
    assert_eq!(r.stats.cycles, 0, "no array invocation was spent");
    let live_r = live_h.wait();
    assert!(live_r.error.is_none(), "{:?}", live_r.error);
    assert_eq!(live_r.output, live_expect, "neighbours are unaffected");
    assert!(!live_r.shed);
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.sheds, 1);
    coord.shutdown();
}

// ----------------------------------------------- lifecycle observability

/// The handle exposes the ticket's lifecycle: a queued job reports
/// `Queued`, and a completed one `Done` — the states the retry and shed
/// paths transition through are covered by the scheduler unit tests.
#[test]
fn handle_state_tracks_the_lifecycle() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 1, k: 16, n: 1 };
    let (job, _) = gemm_job(1, shape, 3);
    let h = coord.submit_job(job).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while !h.is_done() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(h.is_done());
    assert_eq!(h.state(), TicketState::Done);
    let r = h.try_take().unwrap();
    assert!(r.error.is_none(), "{:?}", r.error);
    coord.shutdown();
}
