//! 2-D tiled scatter–gather: one logical GEMM split into a
//! `k_tiles × n_tiles` grid of tile tickets, executed across worker
//! regions, and gathered back bit-exact — same-column partial sums
//! add-reduce before the column ranges concatenate. Covers ad-hoc and
//! pinned-session paths on overlay, custom and mixed pools, ragged and
//! oversubscribed grids, overflow rejection, fault-injected retry of
//! grid tiles, and tile/batch interaction.

use picaso::arch::CustomDesign;
use picaso::backend::{FaultInjector, FaultPlan};
use picaso::compiler::{add_reduce_partials, gemm_ref, gemm_ref_checked, GemmShape};
use picaso::coordinator::{
    BackendHook, BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, RegionSpec,
    TilePolicy,
};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

fn gemm_job(id: u64, shape: GemmShape, width: u16, seed: u64) -> (Job, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, u32::from(width));
    rng.fill_signed(&mut b, u32::from(width));
    let expect = gemm_ref(shape, &a, &b);
    (Job::new(id, JobKind::Gemm { shape, width, a, b }), expect)
}

fn pool(regions: Vec<RegionSpec>) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        geom: ArrayGeometry::new(2, 1),
        regions,
        ..Default::default()
    })
    .unwrap()
}

/// The acceptance matrix: seeded random GEMMs over a sweep of shapes,
/// widths and tile grids — square, ragged (axis % tiles != 0) and
/// oversubscribed (tiles > axis, clamped) — on overlay-only,
/// custom-only and mixed pools, through BOTH the ad-hoc operand-slicing
/// path and the pinned-session staging-table path. Every gathered
/// output must be bit-exact against the scalar i64 reference.
#[test]
fn tiled_gemm_bit_exact_across_pools_grids_and_paths() {
    let overlay = RegionSpec { kind: ArchKind::PICASO_F, count: 1 };
    let comefa = RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 };
    let pools: Vec<(&str, Vec<RegionSpec>)> = vec![
        ("overlay-only", vec![RegionSpec { count: 2, ..overlay }]),
        ("custom-only", vec![RegionSpec { count: 2, ..comefa }]),
        ("mixed", vec![overlay, comefa]),
    ];
    // (shape, width, grids): k = 20 spans multiple row slices on the
    // 2x1 test geometry, so k-splits cut real slice boundaries; 7 and
    // 20 are both ragged against 3; (100, 100) oversubscribes both
    // axes and must clamp to (k, n).
    let cases: Vec<(GemmShape, u16, Vec<(usize, usize)>)> = vec![
        (GemmShape { m: 2, k: 20, n: 7 }, 8, vec![(2, 2), (3, 3), (20, 1), (100, 100)]),
        (GemmShape { m: 3, k: 9, n: 4 }, 4, vec![(2, 3), (9, 4)]),
        (GemmShape { m: 1, k: 12, n: 2 }, 6, vec![(5, 2)]),
    ];
    for (pname, regions) in pools {
        let coord = pool(regions);
        let mut rng = Xoshiro256::seeded(0x711E5);
        let mut id = 0u64;
        for (shape, width, grids) in &cases {
            let mut weights = vec![0i64; shape.k * shape.n];
            rng.fill_signed(&mut weights, u32::from(*width));
            let sid = coord.open_session(*shape, *width, weights.clone()).unwrap();
            for &(kt, nt) in grids {
                let policy = TilePolicy::Grid { k_tiles: kt, n_tiles: nt };
                let ctx = format!("{pname} {shape:?} w{width} grid {kt}x{nt}");
                let want_tiles = kt.min(shape.k) * nt.min(shape.n);
                // Ad-hoc: tiles carry sliced A columns and B blocks.
                let (job, expect) = gemm_job(id, *shape, *width, 0xAD0C + id);
                let r = coord.submit_job(job.with_shards(policy)).unwrap().wait();
                assert!(r.error.is_none(), "{ctx} ad-hoc: {:?}", r.error);
                assert_eq!(r.output, expect, "{ctx} ad-hoc must match gemm_ref");
                assert_eq!(r.shards, want_tiles, "{ctx} ad-hoc");
                assert!(r.stats.cycles > 0, "{ctx}: tile cycles roll up");
                // Session: tiles carry full activations; workers window
                // them and slice the pinned staging table per slot.
                let mut a = vec![0i64; shape.m * shape.k];
                rng.fill_signed(&mut a, u32::from(*width));
                let expect = gemm_ref(*shape, &a, &weights);
                let job = Job::new(id + 1, JobKind::SessionGemm { session: sid, a: a.into() })
                    .with_shards(policy);
                let r = coord.submit_job(job).unwrap().wait();
                assert!(r.error.is_none(), "{ctx} session: {:?}", r.error);
                assert_eq!(r.output, expect, "{ctx} session must match gemm_ref");
                assert_eq!(r.shards, want_tiles, "{ctx} session");
                id += 2;
            }
            coord.close_session(sid);
        }
        let snap = coord.metrics_snapshot();
        assert!(snap.ktiled_jobs > 0, "{pname}: k-splits must hit the tiling lane");
        assert!(snap.max_k_tiles >= 20, "{pname}: clamped k-split recorded");
        coord.shutdown();
    }
}

/// The headline capability: a session whose weight table is far deeper
/// (k = 96 on a 2-lane test geometry, 48 row slices) than any single
/// tile's sub-table executes bit-exact when split along k — each tile
/// stages only its k-range, computes a partial product, and the gather
/// add-reduces. Repeat submissions reuse the per-worker
/// `(session, tile-slot)` caches and must stay bit-exact every round.
#[test]
fn deep_k_session_tiles_reuse_cache_bit_exact() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 3,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 96, n: 5 };
    let mut rng = Xoshiro256::seeded(0xDEE9);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone()).unwrap();
    for round in 0..3u64 {
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        let expect = gemm_ref(shape, &a, &weights);
        let job = Job::new(round, JobKind::SessionGemm { session: sid, a: a.into() })
            .with_shards(TilePolicy::Grid { k_tiles: 4, n_tiles: 2 });
        let r = coord.submit_job(job).unwrap().wait();
        assert!(r.error.is_none(), "round {round}: {:?}", r.error);
        assert_eq!(r.output, expect, "round {round} (cached tile views)");
        assert_eq!(r.shards, 8, "round {round}");
    }
    // All-negative operands: partial sums accumulate negative values
    // through the same add-reduce path.
    let a = vec![-3i64; shape.m * shape.k];
    let expect = gemm_ref(shape, &a, &weights);
    let job = Job::new(9, JobKind::SessionGemm { session: sid, a: a.into() })
        .with_shards(TilePolicy::Grid { k_tiles: 6, n_tiles: 1 });
    let r = coord.submit_job(job).unwrap().wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect, "negative accumulands add-reduce bit-exact");
    coord.shutdown();
}

/// A single-tile grid is the degenerate case: no scatter, no gather, no
/// tiling metrics — byte-identical behaviour to an untiled submission.
#[test]
fn single_tile_grid_degenerates_to_unsharded() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 8, n: 3 };
    let (job, expect) = gemm_job(0, shape, 8, 0x0DE6);
    let h = coord
        .submit_job(job.with_shards(TilePolicy::Grid { k_tiles: 1, n_tiles: 1 }))
        .unwrap();
    assert_eq!(h.shard_count(), 1);
    let r = h.wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect);
    assert_eq!(r.shards, 1);
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.sharded_jobs, 0, "a 1x1 grid never counts as scattered");
    assert_eq!(snap.ktiled_jobs, 0);
    // The normalizing constructor agrees.
    assert_eq!(TilePolicy::grid(1, 1), TilePolicy::None);
    coord.shutdown();
}

/// Failure-domain retry inside a 2-D scatter: with a poisoned region in
/// the pool, the tiles that land there fail transiently, re-queue with
/// that region excluded, and the grid still gathers bit-exact — the
/// parent result reports the retries its tiles consumed.
#[test]
fn grid_tiles_survive_poisoned_region_bit_exact() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 3,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::disabled(),
        backend_hook: Some(BackendHook(Arc::new(|widx, inner| {
            if widx == 0 {
                Box::new(FaultInjector::new(inner, FaultPlan::Poisoned))
            } else {
                inner
            }
        }))),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 20, n: 6 };
    let mut rng = Xoshiro256::seeded(0xFA17);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone()).unwrap();
    let mut total_retries = 0u32;
    for i in 0..6u64 {
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        let (job, expect) = if i % 2 == 0 {
            gemm_job(i, shape, 8, 0xF00 + i)
        } else {
            let expect = gemm_ref(shape, &a, &weights);
            (Job::new(i, JobKind::SessionGemm { session: sid, a: a.into() }), expect)
        };
        let r = coord
            .submit_job(job.with_shards(TilePolicy::Grid { k_tiles: 2, n_tiles: 2 }))
            .unwrap()
            .wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expect, "job {i} bit-exact after tile retry");
        assert_eq!(r.shards, 4, "job {i}");
        total_retries += r.retries;
    }
    assert!(
        total_retries > 0,
        "a poisoned region must have cost at least one tile retry"
    );
    coord.shutdown();
}

/// Tile/batch interaction: sibling tiles of one logical job must never
/// coalesce into one micro-batch (they would serialize on one region,
/// defeating the scatter), so on a single worker with a generous batch
/// window a 2x2 grid still executes as four separate invocations.
#[test]
fn sibling_tiles_do_not_share_a_batch() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_millis(5) },
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 4 };
    let mut rng = Xoshiro256::seeded(0x5B1B);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone()).unwrap();
    let mut a = vec![0i64; shape.m * shape.k];
    rng.fill_signed(&mut a, 8);
    let expect = gemm_ref(shape, &a, &weights);
    let job = Job::new(0, JobKind::SessionGemm { session: sid, a: a.into() })
        .with_shards(TilePolicy::Grid { k_tiles: 2, n_tiles: 2 });
    let r = coord.submit_job(job).unwrap().wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect);
    assert_eq!(r.shards, 4);
    assert_eq!(
        r.batch_size, 1,
        "sibling tiles (and different k-ranges) must not coalesce"
    );
    coord.shutdown();
}

/// The overflow contract, at the library level: the add-reduce rejects
/// partial sums that leave the logical accumulator range (and i64
/// wraparound outright), and the checked scalar reference rejects the
/// same way — operands wider than declared cannot silently wrap.
#[test]
fn partial_sum_overflow_rejected_and_mirrored_by_reference() {
    // acc_bits(2, 2) = 4 + 1 = 5 → range [-16, 15].
    let parts = vec![vec![10i64, -10], vec![10, -10]];
    let err = add_reduce_partials(&parts, 5).unwrap_err().to_string();
    assert!(err.contains("partial-sum overflow"), "{err}");
    // In range: sums to [14, -14].
    let parts = vec![vec![7i64, -7], vec![7, -7]];
    assert_eq!(add_reduce_partials(&parts, 5).unwrap(), vec![14, -14]);
    // i64 wraparound is caught before the range check.
    let parts = vec![vec![i64::MAX], vec![1]];
    let err = add_reduce_partials(&parts, 64).unwrap_err().to_string();
    assert!(err.contains("wraparound"), "{err}");
    // The checked reference rejects over-width operands the same way: a
    // width-2 GEMM whose operands are magnitude 3 overflows the 5-bit
    // accumulator (3*3*2 = 18 > 15)…
    let shape = GemmShape { m: 1, k: 2, n: 1 };
    let err = gemm_ref_checked(shape, 2, &[3, 3], &[3, 3]).unwrap_err().to_string();
    assert!(err.contains("overflow"), "{err}");
    // …while genuinely width-2 operands (and negative sums) pass.
    assert_eq!(gemm_ref_checked(shape, 2, &[-2, -2], &[1, 1]).unwrap(), vec![-4]);
}
