//! Full-stack integration tests: compiler → array simulator → results,
//! cross-checked against software references, the analytic cycle algebra,
//! and (when artifacts are built) the XLA golden models.

use picaso::compiler::{execute_gemm, gemm_ref, GemmShape, PimCompiler};
use picaso::coordinator::{Coordinator, CoordinatorConfig, Job, JobKind};
use picaso::isa::asm;
use picaso::prelude::*;
use picaso::runtime::{artifact, XlaRuntime, ARTIFACTS_DIR};
use picaso::testutil::{check_eq, gen_pow2, gen_signed_vec, prop, run_prop, PropConfig};

// ---------------------------------------------------------------- GEMM

#[test]
fn prop_gemm_matches_reference_across_shapes_and_archs() {
    run_prop(
        "gemm == reference",
        PropConfig { cases: 30, seed: 0x6E66 },
        |rng| {
            let rows = rng.range(1, 5);
            let cols = gen_pow2(rng, 0, 2); // 1..4 blocks per row
            let geom = ArrayGeometry::new(rows, cols);
            let m = rng.range(1, 5);
            let n = rng.range(1, 5);
            let k = rng.range(1, 2 * geom.row_lanes() + 1);
            let width = [4u16, 6, 8][rng.range(0, 3)] as u16;
            let shape = GemmShape { m, k, n };
            let a = gen_signed_vec(rng, m * k, width as u32);
            let b = gen_signed_vec(rng, k * n, width as u32);
            let kind = if rng.bool() {
                ArchKind::Overlay(PipelineConfig::FullPipe)
            } else {
                ArchKind::Spar2
            };
            let plan = PimCompiler::new(geom)
                .gemm(shape, width)
                .map_err(|e| e.to_string())?;
            let mut arr = PimArray::with_kind(geom, kind);
            let (c, stats) = execute_gemm(&mut arr, &plan, &a, &b).map_err(|e| e.to_string())?;
            check_eq(c, gemm_ref(shape, &a, &b), &format!("{kind:?} {shape:?} w={width}"))?;
            if stats.cycles == 0 {
                return Err("zero cycles charged".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_booth_skip_never_changes_results() {
    prop("booth-skip result invariance", |rng| {
        let geom = ArrayGeometry::new(2, 1);
        let shape = GemmShape { m: 2, k: 16, n: 2 };
        let a = gen_signed_vec(rng, 32, 8);
        let b = gen_signed_vec(rng, 32, 8);
        let plan = PimCompiler::new(geom).gemm(shape, 8).map_err(|e| e.to_string())?;
        let run = |skip: bool| -> Result<(Vec<i64>, u64), String> {
            let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
            arr.set_booth_skip(skip);
            let (c, s) = execute_gemm(&mut arr, &plan, &a, &b).map_err(|e| e.to_string())?;
            Ok((c, s.cycles))
        };
        let (c1, cyc1) = run(false)?;
        let (c2, cyc2) = run(true)?;
        check_eq(c1, c2, "results")?;
        if cyc2 > cyc1 {
            return Err(format!("skip increased cycles: {cyc2} > {cyc1}"));
        }
        Ok(())
    });
}

// ------------------------------------------------ cycle-algebra identity

#[test]
fn prop_simulator_cycles_equal_analytic_forms() {
    run_prop(
        "sim cycles == Table V algebra",
        PropConfig { cases: 40, seed: 0xA15 },
        |rng| {
            let cols = gen_pow2(rng, 0, 4); // up to 16 blocks => q up to 256
            let geom = ArrayGeometry::new(1, cols);
            let q = geom.row_lanes();
            let width = [8u16, 16, 32][rng.range(0, 3)];
            let kind = if rng.bool() {
                ArchKind::Overlay(PipelineConfig::FullPipe)
            } else {
                ArchKind::Spar2
            };
            let mut arr = PimArray::with_kind(geom, kind);
            let mut stats = RunStats::default();
            arr.step(
                Instruction::Accumulate { dst: picaso::isa::RfAddr(0), width },
                &mut stats,
            )
            .map_err(|e| e.to_string())?;
            check_eq(
                stats.cycles,
                kind.cycles().accumulate(q, width as u32),
                &format!("{kind:?} q={q} N={width}"),
            )
        },
    );
}

// ------------------------------------------------------------- assembler

#[test]
fn compiled_gemm_roundtrips_through_assembler() {
    let geom = ArrayGeometry::new(2, 2);
    let plan = PimCompiler::new(geom)
        .gemm(GemmShape { m: 4, k: 40, n: 4 }, 8)
        .unwrap();
    let text = asm::format_program(&plan.microcode);
    let parsed = asm::parse_program(&text, plan.width).unwrap();
    assert_eq!(parsed.instrs, plan.microcode.instrs);
}

// ----------------------------------------------------------- coordinator

#[test]
fn coordinator_end_to_end_with_mixed_shapes() {
    let mut coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(4, 2),
        ..Default::default()
    })
    .unwrap();
    let shapes = [
        GemmShape { m: 4, k: 32, n: 4 },
        GemmShape { m: 2, k: 64, n: 3 },
        GemmShape { m: 1, k: 16, n: 8 },
    ];
    let mut rng = picaso::util::Xoshiro256::seeded(77);
    let mut jobs = Vec::new();
    let mut expects = Vec::new();
    for id in 0..9u64 {
        let shape = shapes[id as usize % 3];
        let a = gen_signed_vec(&mut rng, shape.m * shape.k, 8);
        let b = gen_signed_vec(&mut rng, shape.k * shape.n, 8);
        expects.push(gemm_ref(shape, &a, &b));
        jobs.push(Job::new(id, JobKind::Gemm { shape, width: 8, a, b }));
    }
    let (results, _) = coord.run_batch(jobs).unwrap();
    for (i, r) in results.iter().enumerate() {
        assert!(r.error.is_none());
        assert_eq!(r.output, expects[i], "job {i}");
    }
    coord.shutdown();
}

// ------------------------------------------------------------ XLA golden

#[test]
fn pim_gemm_matches_xla_golden_model() {
    let mut rt = match XlaRuntime::cpu(ARTIFACTS_DIR) {
        Ok(rt) => rt,
        Err(e) => panic!("PJRT client failed: {e}"),
    };
    if !rt.has_artifact(artifact::GEMM) {
        eprintln!("skipping golden test: run `make artifacts`");
        return;
    }
    rt.load(artifact::GEMM).unwrap();
    let shape = GemmShape { m: 16, k: 64, n: 16 };
    let mut rng = picaso::util::Xoshiro256::seeded(0x601D);
    let a = gen_signed_vec(&mut rng, shape.m * shape.k, 8);
    let b = gen_signed_vec(&mut rng, shape.k * shape.n, 8);

    // PIM path.
    let geom = ArrayGeometry::new(8, 4);
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
    let (c_pim, _) = execute_gemm(&mut arr, &plan, &a, &b).unwrap();

    // Golden path.
    let c_xla = rt.gemm_golden(shape.m, shape.k, shape.n, &a, &b).unwrap();
    assert_eq!(c_pim, c_xla, "PIM and XLA golden GEMM must agree bit-for-bit");
}

#[test]
fn pallas_bitserial_artifact_matches_sim() {
    let mut rt = XlaRuntime::cpu(ARTIFACTS_DIR).unwrap();
    if !rt.has_artifact(artifact::BITSERIAL) {
        eprintln!("skipping: run `make artifacts`");
        return;
    }
    rt.load(artifact::BITSERIAL).unwrap();
    // The artifact computes 8 row-dot-products over q=64 int8 lanes —
    // the same workload as one 4-block PiCaSO row per sample.
    let mut rng = picaso::util::Xoshiro256::seeded(0xBAD5EED);
    let a = gen_signed_vec(&mut rng, 8 * 64, 8);
    let b = gen_signed_vec(&mut rng, 8 * 64, 8);
    let fa: Vec<f32> = a.iter().map(|&v| v as f32).collect();
    let fb: Vec<f32> = b.iter().map(|&v| v as f32).collect();
    let out = rt
        .run_f32(artifact::BITSERIAL, &[(fa, vec![8, 64]), (fb, vec![8, 64])])
        .unwrap();

    // Simulated overlay: 8 rows of 4 blocks, one MAC group per row.
    let geom = ArrayGeometry::new(8, 4);
    let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
    arr.set_buffer(picaso::compiler::BUF_A, a.clone());
    arr.set_buffer(picaso::compiler::BUF_B, b.clone());
    let mc = MacProgram::elementwise_mul_then_accumulate(8, 64);
    arr.execute(&mc).unwrap();
    for row in 0..8 {
        let pim = arr.row_result(row, picaso::compiler::WL_ACC, 22);
        let pallas = out[row].round() as i64;
        assert_eq!(pim, pallas, "row {row}: PIM sim vs Pallas kernel");
    }
}
