//! Serving-path integration tests: backpressure, micro-batch flush
//! triggers, out-of-order handle completion, and session reuse — all at
//! equal correctness with the software GEMM reference.

use picaso::arch::CustomDesign;
use picaso::compiler::{execute_gemm, execute_gemm_batch, gemm_ref, GemmShape, PimCompiler};
use picaso::coordinator::{
    Backpressure, BatchPolicy, Batcher, Coordinator, CoordinatorConfig, Job, JobKind, QueuePolicy,
    RegionSpec, Scheduler, SchedulerConfig,
};
use picaso::metrics::ServingMetrics;
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn tiny_job(id: u64, shape: GemmShape, seed: u64) -> (Job, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(shape, &a, &b);
    (Job::new(id, JobKind::Gemm { shape, width: 8, a, b }), expect)
}

fn bare_scheduler(cfg: SchedulerConfig) -> Scheduler {
    Scheduler::new(cfg, Arc::new(ServingMetrics::new())).unwrap()
}

// ------------------------------------------------------- backpressure

#[test]
fn reject_backpressure_fails_fast_at_capacity() {
    let shape = GemmShape { m: 1, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig {
        capacity: 3,
        backpressure: Backpressure::Reject,
        ..Default::default()
    });
    for id in 0..3 {
        sched.submit(tiny_job(id, shape, id).0).unwrap();
    }
    let err = sched.submit(tiny_job(3, shape, 3).0).unwrap_err();
    assert!(matches!(err, picaso::Error::Busy(_)), "expected Busy, got {err}");
    assert!(err.to_string().contains("backpressure"), "{err}");
    // Draining one slot re-admits the next submission.
    drop(sched.pop_blocking().unwrap());
    sched.submit(tiny_job(4, shape, 4).0).unwrap();
    assert_eq!(sched.depth(), 3);
}

#[test]
fn block_backpressure_parks_the_submitter_until_a_slot_frees() {
    let shape = GemmShape { m: 1, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig {
        capacity: 1,
        backpressure: Backpressure::Block,
        ..Default::default()
    });
    sched.submit(tiny_job(0, shape, 0).0).unwrap();
    let s2 = sched.clone();
    let t0 = Instant::now();
    let submitter = std::thread::spawn(move || {
        s2.submit(tiny_job(1, shape, 1).0).map(|_| t0.elapsed())
    });
    // Hold the queue full long enough to observe the block, then free it.
    std::thread::sleep(Duration::from_millis(40));
    drop(sched.pop_blocking().unwrap());
    let blocked_for = submitter.join().unwrap().unwrap();
    assert!(
        blocked_for >= Duration::from_millis(30),
        "submitter should have blocked, returned after {blocked_for:?}"
    );
    assert_eq!(sched.depth(), 1);
}

// -------------------------------------------------- batch flush triggers

#[test]
fn batcher_flushes_when_the_batch_is_full() {
    let shape = GemmShape { m: 1, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig::default());
    for id in 0..7 {
        sched.submit(tiny_job(id, shape, id).0).unwrap();
    }
    let batcher = Batcher::new(BatchPolicy::Fixed { max_batch: 4, max_wait: Duration::from_secs(10) });
    let t0 = Instant::now();
    let batch = batcher.collect(&sched).unwrap();
    assert_eq!(batch.len(), 4, "size trigger fires before the 10s budget");
    assert!(t0.elapsed() < Duration::from_secs(5), "did not wait out the budget");
    assert_eq!(sched.depth(), 3);
}

#[test]
fn batcher_flushes_when_the_wait_budget_expires() {
    let shape = GemmShape { m: 1, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig::default());
    sched.submit(tiny_job(0, shape, 0).0).unwrap();
    let batcher = Batcher::new(BatchPolicy::Fixed { max_batch: 64, max_wait: Duration::from_millis(25) });
    let t0 = Instant::now();
    let batch = batcher.collect(&sched).unwrap();
    let waited = t0.elapsed();
    assert_eq!(batch.len(), 1, "nothing to coalesce with");
    assert!(waited >= Duration::from_millis(20), "flushed too early: {waited:?}");
    assert!(waited < Duration::from_secs(2), "hung: {waited:?}");
}

#[test]
fn batcher_only_coalesces_matching_shapes() {
    let small = GemmShape { m: 1, k: 4, n: 1 };
    let big = GemmShape { m: 2, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig::default());
    sched.submit(tiny_job(0, small, 0).0).unwrap();
    sched.submit(tiny_job(1, big, 1).0).unwrap();
    sched.submit(tiny_job(2, small, 2).0).unwrap();
    let batcher = Batcher::new(BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::ZERO });
    let first: Vec<u64> = batcher.collect(&sched).unwrap().iter().map(|t| t.job.id).collect();
    assert_eq!(first, vec![0, 2]);
    let second: Vec<u64> = batcher.collect(&sched).unwrap().iter().map(|t| t.job.id).collect();
    assert_eq!(second, vec![1]);
}

/// Several submitters blocked on a full queue under `Priority` must all
/// be re-admitted as slots free (no lost wakeups), and once admitted the
/// queue must still dispatch in priority order.
#[test]
fn blocked_submitters_under_priority_all_admit_in_order() {
    let shape = GemmShape { m: 1, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig {
        capacity: 2,
        policy: QueuePolicy::Priority,
        backpressure: Backpressure::Block,
        ..Default::default()
    });
    // Fill the queue, then park 6 submitters with distinct priorities.
    sched.submit_with_priority(tiny_job(100, shape, 0).0, 0).unwrap();
    sched.submit_with_priority(tiny_job(101, shape, 1).0, 0).unwrap();
    let mut submitters = Vec::new();
    for p in 1..=6u8 {
        let s = sched.clone();
        submitters.push(std::thread::spawn(move || {
            s.submit_with_priority(tiny_job(p as u64, shape, p as u64).0, p).map(|h| h.id())
        }));
    }
    // Give the submitters time to park, then free exactly enough slots
    // one by one: every wakeup must admit someone (no lost wakeups).
    std::thread::sleep(Duration::from_millis(30));
    let mut freed = Vec::new();
    for _ in 0..6 {
        freed.push(sched.pop_blocking().expect("queue holds tickets"));
        std::thread::sleep(Duration::from_millis(5));
    }
    for t in submitters {
        t.join().expect("submitter must not deadlock").unwrap();
    }
    // All 6 parked submissions are now queued (6 popped, 2+6 submitted).
    assert_eq!(sched.depth(), 2);
    // Drain everything still queued: admitted tickets must come out in
    // priority order (descending), regardless of admission interleaving.
    let mut last = u8::MAX;
    while sched.depth() > 0 {
        let t = sched.pop_blocking().expect("non-empty queue yields a ticket");
        assert!(t.priority <= last, "priority inversion: {} after {last}", t.priority);
        last = t.priority;
    }
    drop(freed);
}

/// A stream of arrivals the worker's class can never take must not keep
/// the batcher spinning past its wait budget: `max_wait` bounds the
/// collection even while the arrival clock keeps moving.
#[test]
fn batcher_max_wait_holds_under_nonmatching_arrival_stream() {
    let comefa = BackendClass::Custom(CustomDesign::CoMeFaA);
    let shape = GemmShape { m: 1, k: 4, n: 1 };
    let sched = bare_scheduler(SchedulerConfig::default());
    // Head-of-line ticket the overlay worker can take.
    let mut head = tiny_job(0, shape, 0).0;
    head.backend = Some(BackendClass::Overlay);
    sched.submit(head).unwrap();
    // Background stream of CoMeFa-only arrivals, each moving the
    // arrival clock the batcher sleeps on.
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let feeder = {
        let sched = sched.clone();
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut id = 1u64;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                let mut j = tiny_job(id, shape, id).0;
                j.backend = Some(comefa);
                if sched.submit(j).is_err() {
                    break;
                }
                id += 1;
                std::thread::sleep(Duration::from_millis(2));
            }
        })
    };
    let batcher = Batcher::new(BatchPolicy::Fixed { max_batch: 64, max_wait: Duration::from_millis(40) });
    let t0 = Instant::now();
    let batch = batcher.collect_for(&sched, None, Some(BackendClass::Overlay)).unwrap();
    let waited = t0.elapsed();
    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    assert_eq!(batch.len(), 1, "only the overlay head was ever eligible");
    assert_eq!(batch[0].job.id, 0);
    assert!(
        waited < Duration::from_millis(400),
        "batcher spun far past its 40ms budget: {waited:?}"
    );
    feeder.join().unwrap();
}

// ------------------------------------------- out-of-order completion

#[test]
fn handles_resolve_out_of_order_and_bit_exact() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 3,
        geom: ArrayGeometry::new(2, 1),
        scheduler: SchedulerConfig { policy: QueuePolicy::Priority, ..Default::default() },
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let mut handles = Vec::new();
    let mut expects = Vec::new();
    for i in 0..10u64 {
        let (job, expect) = tiny_job(i, shape, 0xBEEF + i);
        // Mixed priorities: later submissions may dispatch first.
        handles.push(coord.submit_with_priority(job, (i % 3) as u8).unwrap());
        expects.push(expect);
    }
    // Await in reverse submission order: every handle must resolve on its
    // own, regardless of dispatch or completion order.
    for (i, h) in handles.into_iter().enumerate().rev() {
        let r = h.wait();
        assert_eq!(r.id, i as u64);
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expects[i], "job {i}");
    }
    coord.shutdown();
}

#[test]
fn handle_polling_api() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(1, 1),
        ..Default::default()
    })
    .unwrap();
    let (job, expect) = tiny_job(1, GemmShape { m: 1, k: 8, n: 1 }, 42);
    let h = coord.submit_job(job).unwrap();
    // Bounded poll: the job is tiny, so it completes well within this.
    let deadline = Instant::now() + Duration::from_secs(30);
    while !h.is_done() && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert!(h.is_done(), "job did not complete in 30s");
    let r = h.try_take().expect("done implies takeable");
    assert_eq!(r.output, expect);
    assert!(h.try_take().is_none(), "result is taken exactly once");
    coord.shutdown();
}

// ----------------------------------------------------- session serving

#[test]
fn session_reuse_is_bit_exact_vs_reference() {
    let geom = ArrayGeometry::new(4, 1);
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom,
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 40, n: 3 }; // multi-slice, ragged rounds
    let mut rng = Xoshiro256::seeded(0x5E55);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone()).unwrap();

    let mut handles = Vec::new();
    let mut expects = Vec::new();
    for i in 0..16u64 {
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        expects.push(gemm_ref(shape, &a, &weights));
        handles.push(coord.submit_session(i, sid, a).unwrap());
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expects[i], "job {i} must match gemm_ref bit-for-bit");
    }

    // Repeat inference on the same activations is deterministic.
    let mut a = vec![0i64; shape.m * shape.k];
    rng.fill_signed(&mut a, 8);
    let r1 = coord.submit_session(100, sid, a.clone()).unwrap().wait();
    let r2 = coord.submit_session(101, sid, a.clone()).unwrap().wait();
    assert!(r1.error.is_none() && r2.error.is_none());
    assert_eq!(r1.output, r2.output);
    assert_eq!(r1.output, gemm_ref(shape, &a, &weights));
    coord.shutdown();
}

#[test]
fn closed_session_reports_cleanly() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(1, 1),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 1, k: 8, n: 1 };
    let sid = coord.open_session(shape, 8, vec![1; 8]).unwrap();
    assert!(coord.close_session(sid));
    assert!(!coord.close_session(sid), "second close is a no-op");
    let r = coord.submit_session(1, sid, vec![1; 8]).unwrap().wait();
    assert!(r.error.as_deref().unwrap_or("").contains("not open"), "{:?}", r.error);
    coord.shutdown();
}

// --------------------------------------- batching beats one-at-a-time

/// The acceptance check in deterministic form: the same workload costs
/// strictly fewer simulated PIM cycles through the micro-batched +
/// session path than through the seed one-job-per-invocation path
/// (cycle counts are exact simulator output, so this cannot flake on a
/// loaded machine the way wall-clock throughput could).
#[test]
fn batched_session_serving_charges_fewer_cycles_than_seed_path() {
    let geom = ArrayGeometry::new(4, 1);
    let shape = GemmShape { m: 1, k: 16, n: 3 }; // 3 outputs on 4 rows: ragged
    let jobs = 24u64;
    let mut rng = Xoshiro256::seeded(0xACC);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let mut acts = Vec::new();
    for _ in 0..jobs {
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        acts.push(a);
    }

    let run = |batch: BatchPolicy, use_session: bool| -> u64 {
        let coord = Coordinator::new(CoordinatorConfig {
            workers: 1, // single worker => deterministic batching opportunity
            geom,
            batch,
            ..Default::default()
        })
        .unwrap();
        let sid = if use_session {
            Some(coord.open_session(shape, 8, weights.clone()).unwrap())
        } else {
            None
        };
        let handles: Vec<_> = acts
            .iter()
            .enumerate()
            .map(|(i, a)| match sid {
                Some(sid) => coord.submit_session(i as u64, sid, a.clone()).unwrap(),
                None => coord
                    .submit_job(Job::new(
                        i as u64,
                        JobKind::Gemm { shape, width: 8, a: a.clone(), b: weights.clone() },
                    ))
                    .unwrap(),
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let r = h.wait();
            assert!(r.error.is_none(), "job {i}: {:?}", r.error);
            assert_eq!(r.output, gemm_ref(shape, &acts[i], &weights), "job {i}");
        }
        let snap = coord.metrics_snapshot();
        assert_eq!(snap.jobs, jobs);
        let cycles = snap.pim_cycles;
        coord.shutdown();
        cycles
    };

    let seed_cycles = run(BatchPolicy::disabled(), false);
    let batched_cycles = run(
        BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_millis(20) },
        true,
    );
    assert!(
        batched_cycles < seed_cycles,
        "micro-batching must pack ragged rounds: batched {batched_cycles} !< seed {seed_cycles}"
    );
}

/// Wall-time attribution invariant: per-job `wall_us` shares — weighted
/// by output length, so a poison job in a ragged batch gets no share —
/// sum to the total batch execution time recorded in the `exec` stage.
#[test]
fn ragged_batch_wall_shares_sum_to_batch_wall_time() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_millis(50) },
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let mut handles = Vec::new();
    for i in 0..3u64 {
        handles.push(coord.submit_job(tiny_job(i, shape, 0xEA7 + i).0).unwrap());
    }
    // Poison job: same batch key (same declared shape/width), but the
    // operands do not match — it contributes no output rows.
    handles.push(
        coord
            .submit_job(Job::new(
                3,
                JobKind::Gemm { shape, width: 8, a: vec![0; 2], b: vec![0; 32] },
            ))
            .unwrap(),
    );
    let results: Vec<_> = handles.into_iter().map(|h| h.wait()).collect();
    assert!(results[3].error.is_some(), "poison job must fail");
    for r in &results[..3] {
        assert!(r.error.is_none(), "{:?}", r.error);
    }
    // If the poison job shared a batch with real work, its weighted
    // share must be zero.
    if results[3].batch_size > 1 {
        assert_eq!(results[3].wall_us, 0.0, "no output rows, no wall share");
    }
    let snap = coord.metrics_snapshot();
    let batch_wall_total = snap.exec.mean * snap.exec.count as f64;
    let share_sum: f64 = results.iter().map(|r| r.wall_us).sum();
    assert!(
        (share_sum - batch_wall_total).abs() <= 1e-6 * batch_wall_total.max(1.0),
        "shares {share_sum} != batch wall total {batch_wall_total}"
    );
    coord.shutdown();
}

// ------------------------------------------- heterogeneous routing

/// Jobs tagged for a `BackendClass` must only ever complete on worker
/// regions of that class, even under concurrent mixed load.
#[test]
fn tagged_jobs_never_land_on_a_mismatched_region() {
    let comefa = BackendClass::Custom(CustomDesign::CoMeFaA);
    let coord = Coordinator::new(CoordinatorConfig {
        geom: ArrayGeometry::new(2, 1),
        regions: vec![
            RegionSpec { kind: ArchKind::PICASO_F, count: 2 },
            RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 2 },
        ],
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let mut handles = Vec::new();
    let mut wants = Vec::new();
    for i in 0..24u64 {
        let (mut job, expect) = tiny_job(i, shape, 0x9A0 + i);
        // Mix: overlay-tagged, custom-tagged, and untagged jobs.
        let want = match i % 3 {
            0 => Some(BackendClass::Overlay),
            1 => Some(comefa),
            _ => None,
        };
        job.backend = want;
        handles.push(coord.submit_job(job).unwrap());
        wants.push((want, expect));
    }
    for (i, h) in handles.into_iter().enumerate() {
        let r = h.wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, wants[i].1, "job {i}");
        let ran_on = BackendClass::of(coord.worker_kinds()[r.worker]);
        assert_eq!(r.backend, Some(ran_on), "job {i} result tag");
        if let Some(want) = wants[i].0 {
            assert_eq!(ran_on, want, "job {i} routed to a mismatched region");
        }
    }
    coord.shutdown();
}

/// A mixed-region pool under `Backpressure::Reject` sheds overload with
/// `Error::Busy` but drains everything it admitted — cleanly, on the
/// right regions, and bit-exact.
#[test]
fn mixed_regions_drain_cleanly_under_reject_backpressure() {
    let comefa = BackendClass::Custom(CustomDesign::CoMeFaA);
    let coord = Coordinator::new(CoordinatorConfig {
        geom: ArrayGeometry::new(2, 1),
        regions: vec![
            RegionSpec { kind: ArchKind::PICASO_F, count: 1 },
            RegionSpec { kind: ArchKind::Custom(CustomDesign::CoMeFaA), count: 1 },
        ],
        scheduler: SchedulerConfig {
            capacity: 4,
            backpressure: Backpressure::Reject,
            ..Default::default()
        },
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let mut done = 0u64;
    let mut shed = 0u64;
    let mut i = 0u64;
    while done < 32 {
        // Burst-submit past the queue bound, then drain the admitted
        // handles: rejection (Error::Busy) is load shedding, not failure.
        let mut burst = Vec::new();
        while burst.len() < 8 {
            let (mut job, expect) = tiny_job(i, shape, 0x7777 + i);
            i += 1;
            job.backend = Some(if i % 2 == 0 { BackendClass::Overlay } else { comefa });
            let want = job.backend;
            match coord.submit_job(job) {
                Ok(h) => burst.push((h, expect, want)),
                Err(picaso::Error::Busy(_)) => {
                    shed += 1;
                    break;
                }
                Err(e) => panic!("unexpected error: {e}"),
            }
        }
        for (h, expect, want) in burst {
            let r = h.wait();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.output, expect);
            assert_eq!(r.backend, want);
            done += 1;
        }
        assert!(i < 100_000, "livelock: queue never admits");
    }
    // Every admitted job completed on its tagged region; nothing is
    // stuck in the queue at shutdown.
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.errors, 0);
    assert_eq!(snap.jobs, done);
    assert_eq!(snap.per_backend.len(), 2);
    coord.shutdown();
}

// ---------------------------------------------- packed executor direct

#[test]
fn packed_batch_executor_equals_per_job_executor() {
    let geom = ArrayGeometry::new(2, 2);
    let shape = GemmShape { m: 2, k: 40, n: 2 };
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    let mut operands = Vec::new();
    for t in 0..3u64 {
        let mut rng = Xoshiro256::seeded(0xF00 + t);
        let mut a = vec![0i64; shape.m * shape.k];
        let mut b = vec![0i64; shape.k * shape.n];
        rng.fill_signed(&mut a, 8);
        rng.fill_signed(&mut b, 8);
        operands.push((a, b));
    }
    let items: Vec<(&[i64], &[i64])> =
        operands.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
    let (outs, _) = execute_gemm_batch(&mut arr, &plan, &items).unwrap();
    for (t, (a, b)) in operands.iter().enumerate() {
        let mut solo = PimArray::new(geom, PipelineConfig::FullPipe);
        let (c, _) = execute_gemm(&mut solo, &plan, a, b).unwrap();
        assert_eq!(outs[t], c, "job {t}");
        assert_eq!(outs[t], gemm_ref(shape, a, b), "job {t} vs reference");
    }
}
