//! End-to-end tracing: span-tree well-formedness through the real
//! serving stack, scatter/gather parenting across a poisoned-region
//! retry, per-layer spans under pipelined model requests, the
//! zero-per-job-allocation contract when tracing is off, and Chrome
//! trace-event parse-back through the summarizer.

use picaso::compiler::gemm_ref;
use picaso::prelude::*;
use picaso::trace::summarize_str;
use picaso::util::Xoshiro256;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

// ------------------------------------------------ counting allocator
//
// Every allocation in this test binary is tallied so the
// tracing-off-costs-nothing contract is measurable. The allocator is
// process-global, so tests serialize through `lock()` to keep one
// test's serving run out of another's byte counts.

static ALLOCATED: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCATED.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCATED.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn lock() -> MutexGuard<'static, ()> {
    static TEST_LOCK: Mutex<()> = Mutex::new(());
    TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn gemm_job(id: u64, shape: GemmShape, seed: u64) -> (Job, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(shape, &a, &b);
    (Job::new(id, JobKind::Gemm { shape, width: 8, a, b }), expect)
}

fn traced_pool(workers: usize) -> (Arc<Tracer>, Coordinator) {
    let tracer = Arc::new(Tracer::new(workers));
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::Fixed { max_batch: 4, max_wait: Duration::from_micros(100) },
        trace: Some(Arc::clone(&tracer)),
        ..Default::default()
    })
    .unwrap();
    (tracer, coord)
}

// ------------------------------------------- span-tree well-formedness

/// A traced run — plain jobs plus a 2x2 tiled scatter — produces every
/// lifecycle span, gather/add-reduce parenting holds, and the Chrome
/// export parses back through the summarizer's validation.
#[test]
fn span_tree_well_formed_and_parses_back() {
    let _g = lock();
    let (tracer, coord) = traced_pool(2);
    let shape = GemmShape { m: 2, k: 16, n: 4 };
    for i in 0..4u64 {
        let (job, expect) = gemm_job(i, shape, 0x7A + i);
        let r = coord.submit_job(job).unwrap().wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expect, "job {i}");
    }
    // A 2-D tiled scatter: 2 k-tiles force the add-reduce gather path.
    let (job, expect) = gemm_job(100, shape, 0x77);
    let r = coord
        .submit_job(job.with_shards(ShardPolicy::Grid { k_tiles: 2, n_tiles: 2 }))
        .unwrap()
        .wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect);
    assert_eq!(r.shards, 4);
    coord.shutdown();

    let events = tracer.events();
    let names: Vec<&str> = events.iter().map(|e| e.name.as_str()).collect();
    for want in ["submit", "verify", "queued", "dispatch", "batch", "gather", "add-reduce"] {
        assert!(names.contains(&want), "missing span '{want}' in {names:?}");
    }
    assert!(
        names.iter().any(|n| n.starts_with("round[")),
        "packed rounds must record round[i] spans: {names:?}"
    );
    // The verify child nests under its submission's submit span.
    let verify = events.iter().find(|e| e.name == "verify").unwrap();
    let submit_parent = events
        .iter()
        .find(|e| e.id == verify.parent)
        .expect("verify's parent span is in the journal");
    assert_eq!(submit_parent.name, "submit");
    // add-reduce is a child of the gather span of the same trace.
    let addred = events.iter().find(|e| e.name == "add-reduce").unwrap();
    let gather = events
        .iter()
        .find(|e| e.id == addred.parent)
        .expect("add-reduce's parent span is in the journal");
    assert_eq!(gather.name, "gather");
    assert_eq!(gather.trace, addred.trace, "gather and add-reduce share the logical trace");
    // Every shard ticket of the tiled job shares that one trace id: at
    // least 4 queued spans carry it.
    let shard_queued =
        events.iter().filter(|e| e.trace == addred.trace && e.name == "queued").count();
    assert_eq!(shard_queued, 4, "one queued span per tile shard");
    // Batch windows are fleet-side (trace 0) on worker lanes.
    let batch = events.iter().find(|e| e.name == "batch").unwrap();
    assert_eq!(batch.trace, 0);
    assert!(batch.lane >= 1, "batch spans live on worker lanes");
    assert_eq!(tracer.dropped(), 0);

    // Parse-back: the export validates clean and summarizes.
    let json = TraceSink::to_chrome_json(&tracer);
    assert!(json.contains("\"displayTimeUnit\":\"ms\""), "object-format export");
    assert!(json.contains("serving lanes") && json.contains("logical jobs"));
    let report = summarize_str(&json, "test").unwrap();
    assert!(report.contains("top spans by self-time"), "{report}");
    assert!(report.contains("critical path"), "{report}");
    assert!(report.contains("submit"), "{report}");
}

// ------------------------------- retry parenting on a poisoned region

/// A k-split scatter through a pool with one poisoned region: the
/// failing shard's retry instant, the gather, and the add-reduce all
/// stay on the one logical trace, and the result is bit-exact.
#[test]
fn retry_keeps_scatter_gather_on_one_trace() {
    let _g = lock();
    let tracer = Arc::new(Tracer::new(2));
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        batch: BatchPolicy::disabled(),
        trace: Some(Arc::clone(&tracer)),
        backend_hook: Some(BackendHook(Arc::new(|widx, inner| {
            if widx == 0 {
                Box::new(FaultInjector::new(inner, FaultPlan::Poisoned))
            } else {
                inner
            }
        }))),
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 16, n: 4 };
    // Several k-split jobs: the poisoned region keeps pulling tickets,
    // so at least one shard must travel through a retry.
    let mut total_retries = 0u32;
    for i in 0..6u64 {
        let (job, expect) = gemm_job(i, shape, 0xBEEF + i);
        let r = coord
            .submit_job(job.with_shards(ShardPolicy::Grid { k_tiles: 2, n_tiles: 1 }))
            .unwrap()
            .wait();
        assert!(r.error.is_none(), "job {i}: {:?}", r.error);
        assert_eq!(r.output, expect, "job {i} must stay bit-exact through retry");
        total_retries += r.retries;
    }
    assert!(total_retries >= 1, "the poisoned region must have forced a retry");
    coord.shutdown();

    let events = tracer.events();
    let retry = events
        .iter()
        .find(|e| e.name.starts_with("retry["))
        .expect("a retry[n] instant is recorded");
    assert_ne!(retry.trace, 0, "retries are job-scoped");
    let gather = events
        .iter()
        .find(|e| e.trace == retry.trace && e.name == "gather")
        .expect("the retried shard's logical job still gathers");
    let addred = events
        .iter()
        .find(|e| e.trace == retry.trace && e.name == "add-reduce")
        .expect("k-split gather add-reduces partial sums");
    assert_eq!(addred.parent, gather.id);
    // The shard was re-queued: its trace has more queued spans than
    // shards (the retry re-opens the queued span).
    let queued =
        events.iter().filter(|e| e.trace == retry.trace && e.name == "queued").count();
    assert!(queued >= 3, "2 shards + >=1 re-queue, got {queued}");
}

// --------------------------------------- pipelined model-layer spans

/// Pipelined model requests trace as `model-request` roots with one
/// `layer[i]` child per stage, and the layer jobs' lifecycle spans
/// parent under those layer spans.
#[test]
fn pipelined_requests_trace_per_layer_spans() {
    let _g = lock();
    let (tracer, coord) = traced_pool(2);
    let dims = [12usize, 8, 6];
    let graph = picaso::cli::build_mlp(&dims, 8, "sign", 0xD1).unwrap();
    let requests = 3usize;
    let mut rng = Xoshiro256::seeded(0xF00D);
    let mut inputs = Vec::with_capacity(requests);
    for _ in 0..requests {
        let mut a = vec![0i64; dims[0]];
        rng.fill_signed(&mut a, 8);
        inputs.push(a);
    }
    let expects: Vec<Vec<i64>> =
        inputs.iter().map(|a| graph.forward_ref(a, 1)).collect::<picaso::Result<_>>().unwrap();
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default()).unwrap();
    let exec = GraphExecutor::new(&coord, &model);
    let report = exec.infer_batch(&inputs, ExecMode::Pipelined).unwrap();
    assert_eq!(report.outputs, expects, "traced inference stays bit-exact");
    model.close(&coord);
    coord.shutdown();

    let events = tracer.events();
    let roots: Vec<_> = events.iter().filter(|e| e.name == "model-request").collect();
    assert_eq!(roots.len(), requests, "one root span per request");
    for layer in 0..dims.len() - 1 {
        let name = format!("layer[{layer}]");
        let spans: Vec<_> = events.iter().filter(|e| e.name == name).collect();
        assert_eq!(spans.len(), requests, "one {name} span per request");
        for s in &spans {
            let root = roots
                .iter()
                .find(|r| r.id == s.parent)
                .unwrap_or_else(|| panic!("{name} must parent to a model-request root"));
            assert_eq!(root.trace, s.trace, "layer spans stay on the request's trace");
        }
    }
    // Layer jobs' submit spans parent under a layer span of the same
    // trace (never the 0 root an ad-hoc submission would use).
    let layer_ids: Vec<u64> = events
        .iter()
        .filter(|e| e.name.starts_with("layer["))
        .map(|e| e.id)
        .collect();
    let submits: Vec<_> = events.iter().filter(|e| e.name == "submit").collect();
    assert_eq!(submits.len(), requests * (dims.len() - 1), "one submit per layer job");
    for s in submits {
        assert!(
            layer_ids.contains(&s.parent),
            "submit span {} must nest under a layer span, parent was {}",
            s.id,
            s.parent
        );
    }
    // Distinct requests get distinct traces.
    let mut traces: Vec<u64> = roots.iter().map(|r| r.trace).collect();
    traces.sort_unstable();
    traces.dedup();
    assert_eq!(traces.len(), requests);

    // The export of a model run also validates clean.
    let json = TraceSink::to_chrome_json(&tracer);
    let report = summarize_str(&json, "model").unwrap();
    assert!(report.contains("model-request"), "{report}");
}

// --------------------------------------------- disabled-tracing cost

/// With tracing off, serving N extra jobs allocates the same bytes per
/// job as any other N jobs (no hidden per-job tracing overhead); with
/// tracing on, the per-job byte cost is strictly higher (the spans).
#[test]
fn tracing_off_adds_no_per_job_allocation() {
    let _g = lock();
    fn serve_bytes(jobs: u64, traced: bool) -> u64 {
        let tracer = traced.then(|| Arc::new(Tracer::new(1)));
        let coord = Coordinator::new(CoordinatorConfig {
            workers: 1,
            geom: ArrayGeometry::new(2, 1),
            batch: BatchPolicy::disabled(),
            trace: tracer,
            ..Default::default()
        })
        .unwrap();
        let shape = GemmShape { m: 1, k: 8, n: 2 };
        // Warmup: the first job pays one-off worker/pool setup.
        let (wjob, wexpect) = gemm_job(u64::MAX, shape, 0x5EED);
        assert_eq!(coord.submit_job(wjob).unwrap().wait().output, wexpect);
        let before = ALLOCATED.load(Ordering::Relaxed);
        for i in 0..jobs {
            let (job, expect) = gemm_job(i, shape, 0x999 + i);
            let r = coord.submit_job(job).unwrap().wait();
            assert!(r.error.is_none(), "{:?}", r.error);
            assert_eq!(r.output, expect);
        }
        let bytes = ALLOCATED.load(Ordering::Relaxed) - before;
        coord.shutdown();
        bytes
    }
    // Marginal per-job cost between an N-job and a 2N-job run, so
    // fixed setup cancels out.
    let n = 32u64;
    let marginal = |traced: bool| {
        let small = serve_bytes(n, traced);
        let big = serve_bytes(2 * n, traced);
        big.saturating_sub(small) / n
    };
    let off_a = marginal(false);
    let off_b = marginal(false);
    let on = marginal(true);
    // The untraced per-job cost is reproducible run to run (generous
    // tolerance: scheduling can shift amortized buffer growth).
    let spread = off_a.abs_diff(off_b);
    assert!(
        spread <= off_a.max(off_b) / 2 + 2048,
        "untraced per-job bytes unstable: {off_a} vs {off_b}"
    );
    // Turning tracing on must cost strictly more per job — and
    // therefore tracing off cannot be paying for spans.
    assert!(
        on > off_a.max(off_b),
        "traced per-job bytes ({on}) must exceed untraced ({off_a}/{off_b})"
    );
}

// ------------------------------------------------- summarizer gating

/// The summarizer is a usable CI gate: malformed JSON and unclosed
/// spans fail, a minimal valid journal passes.
#[test]
fn summarizer_accepts_valid_and_rejects_broken_journals() {
    let _g = lock();
    assert!(summarize_str("{not json", "bad").is_err());
    let unclosed = r#"{"traceEvents":[
        {"ph":"X","pid":1,"tid":0,"ts":0.0,"name":"submit",
         "args":{"id":1,"parent":0,"trace":1,"job":0}}]}"#;
    let err = summarize_str(unclosed, "unclosed").unwrap_err();
    assert!(format!("{err}").contains("unclosed"), "{err}");
    let ok = r#"{"displayTimeUnit":"ms","dropped":0,"traceEvents":[
        {"ph":"M","pid":1,"name":"process_name","args":{"name":"serving lanes"}},
        {"ph":"X","pid":1,"tid":0,"ts":0.0,"dur":10.0,"name":"submit",
         "args":{"id":1,"parent":0,"trace":1,"job":0}},
        {"ph":"X","pid":1,"tid":0,"ts":1.0,"dur":4.0,"name":"verify",
         "args":{"id":2,"parent":1,"trace":1,"job":0}}]}"#;
    let report = summarize_str(ok, "tiny").unwrap();
    assert!(report.contains("top spans by self-time"), "{report}");
    assert!(report.contains("verify"), "{report}");
}
