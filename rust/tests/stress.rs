//! Concurrency stress: seeded many-producer / many-worker load over
//! mixed backend-class pools, on both queue layouts
//! ([`QueueSharding::Single`] and the per-class lanes). The properties
//! under test are the ones a sharded queue can silently break:
//!
//! * **no lost wakeups** — every submitted job completes (a dropped
//!   cross-lane notify would strand a worker and hang the drain);
//! * **no class starvation** — with jobs pinned to each class plus an
//!   untagged stream, every region class serves a non-zero share;
//! * **reservation atomicity** — racing scatters against a
//!   [`Backpressure::Reject`] queue either admit every tile or fail
//!   with `Busy`, never a partial scatter;
//! * **bit-exactness** — all of the above at equal correctness with
//!   `gemm_ref`.

use picaso::arch::CustomDesign;
use picaso::compiler::{gemm_ref, GemmShape};
use picaso::coordinator::{
    Backpressure, BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, QueueSharding,
    RegionSpec, SchedulerConfig, ShardPolicy,
};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use picaso::Error;
use std::sync::Arc;
use std::time::Duration;

/// Open-loop drain over a mixed overlay + CoMeFa-A pool: `producers`
/// threads submit their whole quota (blocking only on admission), then
/// wait every handle. Exercises ad-hoc and session jobs, all three lane
/// targets (overlay-pinned, custom-pinned, untagged), and returns once
/// everything verified — a lost wakeup anywhere hangs the drain instead
/// of passing.
fn open_loop_drain(sharding: QueueSharding) {
    let workers = 4;
    let producers = 6;
    let per_producer = 24;
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig {
            workers,
            geom: ArrayGeometry::new(4, 1),
            kind: ArchKind::PICASO_F,
            regions: RegionSpec::mixed_pool(workers),
            batch: BatchPolicy::Fixed { max_batch: 4, max_wait: Duration::from_micros(200) },
            scheduler: SchedulerConfig {
                backpressure: Backpressure::Block,
                sharding,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap(),
    );
    let mut weights = vec![0i64; shape.k * shape.n];
    Xoshiro256::seeded(0x57E55).fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone()).unwrap();
    let weights = Arc::new(weights);
    let tags = [
        None,
        Some(BackendClass::Overlay),
        Some(BackendClass::Custom(CustomDesign::CoMeFaA)),
    ];
    let mut threads = Vec::new();
    for p in 0..producers {
        let coord = Arc::clone(&coord);
        let weights = Arc::clone(&weights);
        threads.push(std::thread::spawn(move || {
            let mut rng = Xoshiro256::seeded(0xD0 + p as u64);
            let mut inflight = Vec::with_capacity(per_producer);
            for j in 0..per_producer {
                let id = (p * 1_000_000 + j) as u64;
                let mut a = vec![0i64; shape.m * shape.k];
                rng.fill_signed(&mut a, 8);
                let expect = gemm_ref(shape, &a, &weights);
                let kind = if j % 2 == 0 {
                    JobKind::Gemm { shape, width: 8, a, b: weights.as_ref().clone() }
                } else {
                    JobKind::SessionGemm { session: sid, a: a.into() }
                };
                let mut job = Job::new(id, kind);
                job.backend = tags[j % tags.len()];
                inflight.push((coord.submit_job(job).unwrap(), expect));
            }
            for (handle, expect) in inflight {
                let r = handle.wait();
                assert!(r.error.is_none(), "producer {p}: {:?}", r.error);
                assert_eq!(r.output, expect, "producer {p} must be bit-exact");
            }
        }));
    }
    for t in threads {
        t.join().expect("producer panicked");
    }
    let snap = coord.metrics_snapshot();
    assert_eq!(
        snap.jobs as usize,
        producers * per_producer,
        "every submission must drain (lost wakeup otherwise)"
    );
    // No class starvation: both region classes served a real share
    // (a starved lane would park its workers while its pinned jobs
    // wait forever — the per-producer waits above would hang first,
    // but the per-backend split makes the sharing visible).
    for class in [
        BackendClass::Overlay,
        BackendClass::Custom(CustomDesign::CoMeFaA),
    ] {
        let served = snap
            .per_backend
            .iter()
            .find(|b| b.backend == class)
            .map_or(0, |b| b.jobs);
        assert!(served > 0, "{} served nothing", class.name());
    }
    // The perf lane observed the traffic: every dispatch is a pop.
    assert!(snap.pops >= snap.jobs, "pops {} < jobs {}", snap.pops, snap.jobs);
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

#[test]
fn open_loop_mixed_pool_drains_bit_exact_single_lane() {
    open_loop_drain(QueueSharding::Single);
}

#[test]
fn open_loop_mixed_pool_drains_bit_exact_per_class() {
    open_loop_drain(QueueSharding::PerClass);
}

/// Racing sharded submissions against a small `Reject` queue: a scatter
/// reserves all its tile slots atomically, so every submission either
/// returns a handle whose gather sees the full shard set, or fails with
/// `Error::Busy` leaving nothing queued. Partial admission would show up
/// as a wrong shard count, a wrong (partial) output, or a stuck drain.
#[test]
fn scatter_reservation_is_atomic_under_reject() {
    let shape = GemmShape { m: 2, k: 12, n: 4 };
    let shards = 4;
    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig {
            workers: 1,
            geom: ArrayGeometry::new(2, 1),
            batch: BatchPolicy::disabled(),
            scheduler: SchedulerConfig {
                capacity: 2 * shards, // at most two scatters queued
                backpressure: Backpressure::Reject,
                ..Default::default()
            },
            ..Default::default()
        })
        .unwrap(),
    );
    let producers = 4;
    let per_producer = 8;
    let mut threads = Vec::new();
    for p in 0..producers {
        let coord = Arc::clone(&coord);
        threads.push(std::thread::spawn(move || -> (usize, usize) {
            let mut rng = Xoshiro256::seeded(0xA70 + p as u64);
            let (mut served, mut rejected) = (0, 0);
            for j in 0..per_producer {
                let id = (p * 1_000 + j) as u64;
                let mut a = vec![0i64; shape.m * shape.k];
                let mut b = vec![0i64; shape.k * shape.n];
                rng.fill_signed(&mut a, 8);
                rng.fill_signed(&mut b, 8);
                let expect = gemm_ref(shape, &a, &b);
                let job = Job::new(id, JobKind::Gemm { shape, width: 8, a, b })
                    .with_shards(ShardPolicy::Fixed(shards));
                loop {
                    match coord.submit_job(job.clone()) {
                        Ok(h) => {
                            let r = h.wait();
                            assert!(r.error.is_none(), "{:?}", r.error);
                            assert_eq!(
                                r.shards, shards,
                                "admitted scatter must carry its full shard set"
                            );
                            assert_eq!(r.output, expect, "gathered output must be bit-exact");
                            served += 1;
                            break;
                        }
                        Err(Error::Busy(_)) => {
                            // All-or-none refusal: nothing of this
                            // scatter queued; back off and retry.
                            rejected += 1;
                            std::thread::sleep(Duration::from_micros(100));
                        }
                        Err(e) => panic!("unexpected submit error: {e}"),
                    }
                }
            }
            (served, rejected)
        }));
    }
    let (mut served, mut rejected) = (0, 0);
    for t in threads {
        let (s, r) = t.join().expect("producer panicked");
        served += s;
        rejected += r;
    }
    assert_eq!(served, producers * per_producer, "every scatter eventually admits");
    assert!(
        rejected > 0,
        "an 8-slot queue under 4 racing producers must refuse at least once \
         (otherwise this test exercised no contention)"
    );
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}

/// Bursty arrival pattern on the per-class layout: quiet gaps between
/// bursts force workers to park on their class lanes and be re-woken by
/// cross-lane publishes — the lost-wakeup shape a shared-condvar design
/// never exhibits. Completion of every burst is the assertion.
#[test]
fn bursty_submission_never_strands_a_worker() {
    let workers = 3;
    let shape = GemmShape { m: 2, k: 8, n: 2 };
    let coord = Arc::new(
        Coordinator::new(CoordinatorConfig {
            workers,
            geom: ArrayGeometry::new(4, 1),
            kind: ArchKind::PICASO_F,
            regions: RegionSpec::mixed_pool(workers),
            batch: BatchPolicy::Adaptive { max_batch: 8, max_wait: Duration::from_millis(2) },
            ..Default::default()
        })
        .unwrap(),
    );
    let mut rng = Xoshiro256::seeded(0xB0057);
    let tags = [
        Some(BackendClass::Overlay),
        Some(BackendClass::Custom(CustomDesign::CoMeFaA)),
        None,
    ];
    for burst in 0..6u64 {
        let mut inflight = Vec::new();
        for j in 0..9usize {
            let mut a = vec![0i64; shape.m * shape.k];
            let mut b = vec![0i64; shape.k * shape.n];
            rng.fill_signed(&mut a, 8);
            rng.fill_signed(&mut b, 8);
            let expect = gemm_ref(shape, &a, &b);
            let mut job = Job::new(burst * 100 + j as u64, JobKind::Gemm { shape, width: 8, a, b });
            job.backend = tags[j % tags.len()];
            inflight.push((coord.submit_job(job).unwrap(), expect));
        }
        for (h, expect) in inflight {
            let r = h.wait();
            assert!(r.error.is_none(), "burst {burst}: {:?}", r.error);
            assert_eq!(r.output, expect, "burst {burst}");
        }
        // Idle gap: workers park on their lanes before the next burst.
        std::thread::sleep(Duration::from_millis(3));
    }
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
}
