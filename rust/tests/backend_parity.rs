//! Backend-parity integration tests: the same compiled GEMM plan
//! submitted through every `PimBackend` — the PiCaSO overlay (all
//! pipeline configurations), SPAR-2, and every custom tile design — must
//! be bit-exact against the software reference `gemm_ref`, including
//! negative operands, multi-slice dot products and ragged final rounds.
//! This is the apples-to-apples guarantee behind the paper's
//! overlay-vs-overhaul comparison: identical data semantics, divergent
//! cycle models.

use picaso::arch::{ArchKind, CustomDesign, PipelineConfig};
use picaso::backend::{make_backend, BackendClass, PimBackend};
use picaso::compiler::{execute_gemm, execute_gemm_batch, gemm_ref, GemmShape, PimCompiler};
use picaso::coordinator::{ModelSession, SessionSpec};
use picaso::prelude::ArrayGeometry;
use picaso::util::Xoshiro256;

/// Every design the study compares.
fn all_kinds() -> Vec<ArchKind> {
    let mut kinds: Vec<ArchKind> =
        PipelineConfig::ALL.iter().map(|c| ArchKind::Overlay(*c)).collect();
    kinds.push(ArchKind::Spar2);
    kinds.extend(CustomDesign::ALL.iter().map(|d| ArchKind::Custom(*d)));
    kinds
}

fn random_operands(shape: GemmShape, width: u32, seed: u64) -> (Vec<i64>, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, width);
    rng.fill_signed(&mut b, width);
    (a, b)
}

#[test]
fn every_backend_is_bit_exact_vs_gemm_ref() {
    // Multi-slice (k=40 over q=16 lanes → 3 slices, ragged tail lanes)
    // and ragged rounds (9 outputs on 2 rows → 5 rounds, last ragged).
    let geom = ArrayGeometry::new(2, 1);
    let shape = GemmShape { m: 3, k: 40, n: 3 };
    let (a, b) = random_operands(shape, 8, 0xA11);
    assert!(a.iter().any(|&v| v < 0), "negative operands must be exercised");
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    assert!(plan.slices >= 3 && (shape.m * shape.n) % geom.rows != 0);
    let expect = gemm_ref(shape, &a, &b);
    for kind in all_kinds() {
        let mut backend = make_backend(kind, geom, false);
        assert_eq!(backend.class(), BackendClass::of(kind));
        let (c, stats) = execute_gemm(&mut *backend, &plan, &a, &b).unwrap();
        assert_eq!(c, expect, "{} diverges from gemm_ref", kind.name());
        assert!(stats.cycles > 0, "{}", kind.name());
    }
}

#[test]
fn custom_cycle_charges_differ_from_overlay_on_the_same_plan() {
    // Same instruction stream, per-design cycle models: the custom tiles
    // charge RMW-cycle costs (Table VIII), the overlays Table V costs.
    let geom = ArrayGeometry::new(1, 1);
    let shape = GemmShape { m: 1, k: 16, n: 1 };
    let (a, b) = random_operands(shape, 8, 0xB22);
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    let run = |kind: ArchKind| {
        let mut backend = make_backend(kind, geom, false);
        let (c, stats) = execute_gemm(&mut *backend, &plan, &a, &b).unwrap();
        assert_eq!(c, gemm_ref(shape, &a, &b), "{}", kind.name());
        stats
    };
    let overlay = run(ArchKind::PICASO_F);
    let ccb = run(ArchKind::Custom(CustomDesign::Ccb));
    let amod = run(ArchKind::Custom(CustomDesign::AMod));
    // MULT at N=8: overlay 144 vs custom 86 (Table VIII rows (a)/(b)).
    assert_eq!(overlay.breakdown.mult, 144);
    assert_eq!(ccb.breakdown.mult, 86);
    assert_eq!(amod.breakdown.mult, 86);
    // Accumulation: the Mod designs' fused OpMux beats the copy tree.
    assert!(amod.breakdown.accumulate < ccb.breakdown.accumulate);
    // No Booth datapath on custom tiles.
    assert_eq!(ccb.booth_total_steps, 0);
    assert!(overlay.booth_total_steps > 0);
}

#[test]
fn batched_execution_matches_per_job_on_every_backend() {
    let geom = ArrayGeometry::new(4, 1);
    let shape = GemmShape { m: 1, k: 16, n: 3 }; // 3 outputs on 4 rows: ragged
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    let mut operands = Vec::new();
    for t in 0..5u64 {
        operands.push(random_operands(shape, 8, 0xC33 + t));
    }
    let items: Vec<(&[i64], &[i64])> =
        operands.iter().map(|(a, b)| (a.as_slice(), b.as_slice())).collect();
    for kind in all_kinds() {
        let mut backend = make_backend(kind, geom, false);
        let (outs, batch_stats) = execute_gemm_batch(&mut *backend, &plan, &items).unwrap();
        let mut solo_cycles = 0u64;
        for (t, (a, b)) in operands.iter().enumerate() {
            assert_eq!(outs[t], gemm_ref(shape, a, b), "{} job {t}", kind.name());
            let mut solo = make_backend(kind, geom, false);
            let (c, s) = execute_gemm(&mut *solo, &plan, a, b).unwrap();
            assert_eq!(c, outs[t], "{} batched == per-job, job {t}", kind.name());
            solo_cycles += s.cycles;
        }
        // Round packing helps every backend: 15 outputs in 4 rounds
        // instead of 5 ragged single-job rounds.
        assert!(
            batch_stats.cycles < solo_cycles,
            "{}: batch {} !< solo {solo_cycles}",
            kind.name(),
            batch_stats.cycles
        );
    }
}

#[test]
fn sessions_serve_identically_on_every_backend() {
    let geom = ArrayGeometry::new(2, 1);
    let shape = GemmShape { m: 2, k: 20, n: 3 }; // multi-slice + ragged
    let mut rng = Xoshiro256::seeded(0xD44);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let spec = SessionSpec { shape, width: 8, weights: weights.clone(), backend: None };
    let session = ModelSession::prepare(&PimCompiler::new(geom), &spec).unwrap();
    let mut a = vec![0i64; shape.m * shape.k];
    rng.fill_signed(&mut a, 8);
    let expect = gemm_ref(shape, &a, &weights);
    for kind in all_kinds() {
        let mut backend = make_backend(kind, geom, false);
        let (c, stats) = session.infer(&mut *backend, &a).unwrap();
        assert_eq!(c, expect, "{} session inference", kind.name());
        assert!(stats.cycles > 0);
    }
}

#[test]
fn worst_case_negative_operands_hit_the_widened_accumulator() {
    // All-(-128) int8 operands over k=64: the exact-precision accumulator
    // (2·8 + 6 = 22 bits) must carry the same value on every backend.
    let geom = ArrayGeometry::new(1, 4); // q = 64
    let shape = GemmShape { m: 1, k: 64, n: 1 };
    let a = vec![-128i64; 64];
    let b = vec![-128i64; 64];
    let plan = PimCompiler::new(geom).gemm(shape, 8).unwrap();
    assert!(plan.acc_width >= 22);
    for kind in all_kinds() {
        let mut backend = make_backend(kind, geom, false);
        let (c, _) = execute_gemm(&mut *backend, &plan, &a, &b).unwrap();
        assert_eq!(c[0], 64 * 128 * 128, "{}", kind.name());
    }
}
