//! Failure-injection tests: every layer must fail *loudly and precisely*
//! on bad inputs — silent wraparound or UB in a simulator invalidates the
//! study it backs.

use picaso::compiler::{execute_gemm, GemmShape, PimCompiler};
use picaso::coordinator::{Coordinator, CoordinatorConfig, Job, JobKind};
use picaso::isa::{asm, BufId, Instruction, RfAddr};
use picaso::prelude::*;

#[test]
fn load_from_unbound_buffer_fails() {
    let mut arr = PimArray::new(ArrayGeometry::new(1, 1), PipelineConfig::FullPipe);
    let mut mc = Microcode::new("bad", 8);
    mc.push(Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(3) });
    let err = arr.execute(&mc).unwrap_err();
    assert!(err.to_string().contains("buf3"), "{err}");
}

#[test]
fn register_file_overflow_fails() {
    let mut arr = PimArray::new(ArrayGeometry::new(1, 1), PipelineConfig::FullPipe);
    let mut stats = RunStats::default();
    // 1024-deep register file: an op ending past wordline 1024 must fail.
    let err = arr
        .step(
            Instruction::Alu {
                op: AluOp::Add,
                dst: RfAddr(1020),
                x: RfAddr(0),
                y: RfAddr(8),
                width: 8,
            },
            &mut stats,
        )
        .unwrap_err();
    assert!(err.to_string().contains("register file depth"), "{err}");
    // Mult writes 2w bits.
    let err = arr
        .step(
            Instruction::Mult { dst: RfAddr(1010), mand: RfAddr(0), mier: RfAddr(8), width: 8 },
            &mut stats,
        )
        .unwrap_err();
    assert!(err.to_string().contains("register file depth"), "{err}");
}

#[test]
fn non_pow2_reduction_fails_with_config_error() {
    let mut arr = PimArray::new(ArrayGeometry::new(1, 3), PipelineConfig::FullPipe);
    let mut stats = RunStats::default();
    let err = arr
        .step(Instruction::Accumulate { dst: RfAddr(0), width: 8 }, &mut stats)
        .unwrap_err();
    assert!(err.to_string().contains("power of two"), "{err}");
}

#[test]
fn fold_level_out_of_range_fails() {
    let mut arr = PimArray::new(ArrayGeometry::new(1, 1), PipelineConfig::FullPipe);
    let mut stats = RunStats::default();
    for level in [0u8, 5] {
        let err = arr
            .step(
                Instruction::Fold {
                    pattern: picaso::isa::FoldPattern::Halving,
                    level,
                    dst: RfAddr(0),
                    width: 8,
                },
                &mut stats,
            )
            .unwrap_err();
        assert!(err.to_string().contains("fold level"), "{err}");
    }
}

#[test]
fn shrinking_extend_fails() {
    let mut arr = PimArray::new(ArrayGeometry::new(1, 1), PipelineConfig::FullPipe);
    let mut stats = RunStats::default();
    let err = arr
        .step(Instruction::Extend { dst: RfAddr(0), from: 16, to: 8 }, &mut stats)
        .unwrap_err();
    assert!(err.to_string().contains("shrinks"), "{err}");
}

#[test]
fn compiler_rejects_degenerate_shapes() {
    let c = PimCompiler::new(ArrayGeometry::new(2, 2));
    for shape in [
        GemmShape { m: 0, k: 8, n: 8 },
        GemmShape { m: 8, k: 0, n: 8 },
        GemmShape { m: 8, k: 8, n: 0 },
    ] {
        assert!(c.gemm(shape, 8).is_err(), "{shape:?}");
    }
    assert!(c.gemm(GemmShape { m: 1, k: 1, n: 1 }, 0).is_err());
    assert!(c.gemm(GemmShape { m: 1, k: 1, n: 1 }, 32).is_err());
}

#[test]
fn executor_rejects_wrong_operand_sizes() {
    let geom = ArrayGeometry::new(1, 1);
    let plan = PimCompiler::new(geom).gemm(GemmShape { m: 2, k: 4, n: 2 }, 8).unwrap();
    let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
    assert!(execute_gemm(&mut arr, &plan, &[1; 7], &[1; 8]).is_err());
    assert!(execute_gemm(&mut arr, &plan, &[1; 8], &[1; 9]).is_err());
}

#[test]
fn coordinator_surfaces_worker_errors_without_dying() {
    let mut coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        ..Default::default()
    })
    .unwrap();
    // One poison job among good ones.
    let good_shape = GemmShape { m: 2, k: 16, n: 2 };
    for id in 0..4u64 {
        let (a, b) = if id == 2 {
            (vec![0i64; 1], vec![0i64; 1]) // wrong sizes
        } else {
            (vec![1i64; 32], vec![1i64; 32])
        };
        coord
            .submit(Job::new(id, JobKind::Gemm { shape: good_shape, width: 8, a, b }))
            .unwrap();
    }
    let mut results = coord.drain(4).unwrap();
    results.sort_by_key(|r| r.id);
    assert!(results[2].error.is_some(), "poison job must report");
    for id in [0usize, 1, 3] {
        assert!(results[id].error.is_none(), "job {id} must survive");
        assert_eq!(results[id].output, vec![16i64; 4]);
    }
    coord.shutdown();
}

#[test]
fn assembler_rejects_malformed_programs() {
    for (src, needle) in [
        ("FROB r1, r2", "unknown mnemonic"),
        ("ADD r1, r2, r3", "expects 4"),
        ("ADD rX, r2, r3, w=8", "bad register"),
        ("MULT r1, r2, r3, w=0", "bad width"),
        ("FOLD.H x, r1, w=8", "bad level"),
        ("LOAD r0, w=8, bufZ", "bad buffer"),
    ] {
        let err = asm::parse_program(src, 8).unwrap_err();
        assert!(err.to_string().contains(needle), "{src}: {err}");
    }
}

#[test]
fn custom_tile_scratch_depth_guard() {
    use picaso::custom::CustomTile;
    let mut tile = CustomTile::new(CustomDesign::Ccb);
    // Accumulating with a scratch window beyond 256 wordlines must fail
    // (the Fig 7 scarcity made concrete).
    let vals = vec![1i64; 16];
    tile.write_values(0, 16, &vals).unwrap();
    assert!(tile.accumulate(0, 16, 16, 250).is_err());
    // q beyond the 144 physical bitlines must fail too.
    let huge_q = 256;
    assert!(tile.accumulate(0, 16, huge_q, 64).is_err());
    // And a legal window still works.
    assert!(tile.accumulate(0, 16, 16, 64).is_ok());
}

#[test]
fn runtime_missing_artifact_is_an_error_not_a_crash() {
    let rt = picaso::runtime::XlaRuntime::cpu("/nonexistent-dir");
    let mut rt = rt.expect("client still constructs");
    let err = rt.load("nope").unwrap_err();
    assert!(err.to_string().contains("nope"), "{err}");
}
