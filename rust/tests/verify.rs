//! Static-verifier integration tests: one targeted negative test per
//! defect class, a clean-sweep over every compiler-emitted program, and
//! the admission-gate contract — an enforcing coordinator rejects a
//! refuted program before any scheduler slot is debited.

use picaso::compiler::gemm_ref;
use picaso::isa::{BufId, FoldPattern, RfAddr};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use picaso::Error;

const GEOM: ArrayGeometry = ArrayGeometry { rows: 2, cols: 2 };

fn overlay_ctx() -> VerifyCtx {
    VerifyCtx::new(ArchKind::PICASO_F, GEOM)
}

fn mc(instrs: &[Instruction]) -> Microcode {
    let mut m = Microcode::new("t", 8);
    for i in instrs {
        m.push(*i);
    }
    m
}

/// A program every backend refutes: it reads a wordline nothing wrote,
/// from a range past every design's register file.
fn refuted_program() -> Microcode {
    mc(&[Instruction::Store { src: RfAddr(1020), width: 8, buf: BufId(0) }])
}

// --------------------------------------- one negative test per class

#[test]
fn defect_rf_capacity_is_refuted() {
    // 250+8 fits the overlay's 1024-deep RF but not a custom tile's
    // 256 rows (Table VIII).
    let prog = mc(&[Instruction::Load { dst: RfAddr(250), width: 8, buf: BufId(0) }]);
    assert!(verify(&prog, &overlay_ctx()).is_clean());
    let custom = VerifyCtx::new(ArchKind::Custom(CustomDesign::CoMeFaA), GEOM);
    let report = verify(&prog, &custom);
    assert!(report.has_errors(), "{}", report.render());
    assert!(report.render().contains("depth 256"), "{}", report.render());
}

#[test]
fn defect_uninitialized_read_is_refuted() {
    let prog = mc(&[Instruction::Store { src: RfAddr(0), width: 8, buf: BufId(0) }]);
    let report = verify(&prog, &overlay_ctx());
    assert!(report.has_errors(), "{}", report.render());
    assert!(report.render().contains("before any write"), "{}", report.render());
    // Declaring the operand staged (the session path) silences it.
    let ctx = overlay_ctx().with_preinit(RfAddr(0), 8);
    assert!(verify(&prog, &ctx).is_clean());
}

#[test]
fn defect_hazard_overlap_is_refuted() {
    // dst shifted 4 wordlines into a live 8-wide source: a partial
    // overlap clobbers planes the op still reads. Same-base in-place
    // stays legal (the compiler's Add-into-partial idiom).
    let load = Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) };
    let bad = Instruction::Alu {
        op: AluOp::Add,
        dst: RfAddr(4),
        x: RfAddr(0),
        y: RfAddr(0),
        width: 8,
    };
    let report = verify(&mc(&[load, bad]), &overlay_ctx());
    assert!(report.has_errors(), "{}", report.render());
    assert!(report.render().contains("partially overlaps"), "{}", report.render());
    let ok = Instruction::Alu {
        op: AluOp::Add,
        dst: RfAddr(0),
        x: RfAddr(0),
        y: RfAddr(0),
        width: 8,
    };
    assert!(verify(&mc(&[load, ok]), &overlay_ctx()).is_clean());
}

#[test]
fn defect_width_unsoundness_is_refuted() {
    // ACCUM at w=16 over 16 lanes of 16-significant-bit products needs
    // 16 + log2(16) = 20 bits: an error once the reduction length is
    // declared, a lint without it.
    let prog = mc(&[
        Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) },
        Instruction::Load { dst: RfAddr(8), width: 8, buf: BufId(1) },
        Instruction::Mult { dst: RfAddr(32), mand: RfAddr(0), mier: RfAddr(8), width: 8 },
        Instruction::Accumulate { dst: RfAddr(32), width: 16 },
    ]);
    let lint = verify(&prog, &overlay_ctx());
    assert!(!lint.has_errors(), "{}", lint.render());
    assert!(!lint.is_clean(), "the overflow risk must at least lint");
    let strict = verify(&prog, &overlay_ctx().with_summands(64));
    assert!(strict.has_errors(), "{}", strict.render());
    assert!(strict.render().contains("can overflow"), "{}", strict.render());
    // EXT must strictly widen.
    let ext = mc(&[
        Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) },
        Instruction::Extend { dst: RfAddr(0), from: 8, to: 8 },
    ]);
    let report = verify(&ext, &overlay_ctx());
    assert!(report.has_errors(), "{}", report.render());
    assert!(report.render().contains("not widening"), "{}", report.render());
}

#[test]
fn defect_missing_capability_is_refuted() {
    // FOLD needs the overlay's OpMux datapath; plain custom tiles only
    // reduce through ACCUM (§V).
    let prog = mc(&[
        Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) },
        Instruction::Fold {
            pattern: FoldPattern::Halving,
            level: 1,
            dst: RfAddr(0),
            width: 8,
        },
    ]);
    assert!(!verify(&prog, &overlay_ctx()).has_errors());
    let ccb = VerifyCtx::new(ArchKind::Custom(CustomDesign::Ccb), GEOM);
    let report = verify(&prog, &ccb);
    assert!(report.has_errors(), "{}", report.render());
    assert!(report.render().contains("ACCUM only"), "{}", report.render());
    // A fold level past the 16-lane block is refuted everywhere.
    let deep = mc(&[
        Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) },
        Instruction::Fold {
            pattern: FoldPattern::Halving,
            level: 5,
            dst: RfAddr(0),
            width: 8,
        },
    ]);
    let report = verify(&deep, &overlay_ctx());
    assert!(report.has_errors(), "{}", report.render());
    // booth_skip on a design without a Booth datapath is a lint, not a
    // refutation (Table VIII).
    let mult = mc(&[
        Instruction::Load { dst: RfAddr(0), width: 8, buf: BufId(0) },
        Instruction::Load { dst: RfAddr(8), width: 8, buf: BufId(1) },
        Instruction::Mult { dst: RfAddr(32), mand: RfAddr(0), mier: RfAddr(8), width: 8 },
    ]);
    let ctx = VerifyCtx::new(ArchKind::Custom(CustomDesign::Ccb), GEOM).with_booth_skip(true);
    let report = verify(&mult, &ctx);
    assert!(!report.has_errors(), "{}", report.render());
    assert_eq!(report.warnings(), 1, "{}", report.render());
}

// ----------------------------------------------- compiler clean sweep

#[test]
fn every_compiler_emitted_program_verifies_clean() {
    // The "no false positives" half of the contract: every program the
    // compiler can emit must verify with zero findings on every design
    // it can execute on, across shapes that exercise remainder tiles,
    // multi-slice reductions, and the full width range.
    let all_kinds = [
        ArchKind::PICASO_F,
        ArchKind::Spar2,
        ArchKind::Custom(CustomDesign::Ccb),
        ArchKind::Custom(CustomDesign::CoMeFaD),
        ArchKind::Custom(CustomDesign::CoMeFaA),
        ArchKind::Custom(CustomDesign::AMod),
        ArchKind::Custom(CustomDesign::DMod),
    ];
    let geoms = [ArrayGeometry::new(2, 1), ArrayGeometry::new(2, 2), ArrayGeometry::new(8, 4)];
    let shapes = [
        GemmShape { m: 1, k: 1, n: 1 },
        GemmShape { m: 2, k: 16, n: 2 },
        GemmShape { m: 3, k: 70, n: 5 },
        GemmShape { m: 4, k: 64, n: 8 },
        GemmShape { m: 7, k: 100, n: 3 },
    ];
    for geom in geoms {
        let compiler = PimCompiler::new(geom);
        for shape in shapes {
            for width in [1u16, 4, 8, 16] {
                let plan = compiler.gemm(shape, width).unwrap();
                let report =
                    verify_on_pool(&plan.microcode, geom, &all_kinds, false, Some(shape.k));
                assert!(
                    report.is_clean(),
                    "gemm {shape:?} w={width} {geom:?}:\n{}",
                    report.render()
                );
            }
        }
    }
    // The canned MAC workloads: mul+accumulate runs everywhere; the
    // fold-based pooling workload is overlay-datapath-only by design.
    for geom in geoms {
        let q = geom.row_lanes();
        let mac = MacProgram::elementwise_mul_then_accumulate(8, q);
        let report = verify_on_pool(&mac, geom, &all_kinds, false, Some(q));
        assert!(report.is_clean(), "mac on {geom:?}:\n{}", report.render());
        let add = MacProgram::elementwise_add(8);
        let report = verify_on_pool(&add, geom, &all_kinds, false, None);
        assert!(report.is_clean(), "add on {geom:?}:\n{}", report.render());
        let pool = MacProgram::max_pool(8, 2);
        let overlayish = [ArchKind::PICASO_F, ArchKind::Spar2];
        let report = verify_on_pool(&pool, geom, &overlayish, false, None);
        assert!(report.is_clean(), "maxpool on {geom:?}:\n{}", report.render());
    }
}

// ------------------------------------------------- the admission gate

#[test]
fn enforce_rejects_before_any_scheduler_slot_is_debited() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        verify: VerifyMode::Enforce,
        ..Default::default()
    })
    .unwrap();
    // The admission gate refutes a hand-built bad program outright...
    let err = coord.verify_program(&refuted_program(), 4, None).unwrap_err();
    assert!(matches!(err, Error::Verify(_)), "expected Error::Verify, got {err}");
    assert!(err.to_string().contains("refuted"), "{err}");
    // ...and the rejection never touched the scheduler: the queue-depth
    // high-water mark is still zero, with the rejection on the books.
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.verify_rejects, 1, "rejection must land in the verify lane");
    assert_eq!(snap.depth_hwm, 0, "a refuted program must never debit a queue slot");
    // A clean compiled job passes the same gate and executes bit-exact.
    let shape = GemmShape { m: 2, k: 8, n: 2 };
    let mut rng = Xoshiro256::seeded(9);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(shape, &a, &b);
    let h = coord
        .submit_job(Job::new(1, JobKind::Gemm { shape, width: 8, a, b }))
        .unwrap();
    let r = h.wait();
    assert!(r.error.is_none(), "{:?}", r.error);
    assert_eq!(r.output, expect);
    let snap = coord.metrics_snapshot();
    assert!(snap.verify_passes >= 1, "the clean admission must count as a pass");
    assert!(snap.depth_hwm >= 1, "the admitted job does reach the scheduler");
}

#[test]
fn warn_mode_counts_findings_but_admits() {
    // Warn (opt-in; the default is Enforce) lints: the same refuted
    // program passes through with its findings tallied in the metrics
    // verify lane.
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        verify: VerifyMode::Warn,
        ..Default::default()
    })
    .unwrap();
    coord.verify_program(&refuted_program(), 4, None).unwrap();
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.verify_warns, 1);
    assert_eq!(snap.verify_rejects, 0);
}

#[test]
fn off_mode_skips_verification_entirely() {
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 1,
        geom: ArrayGeometry::new(2, 1),
        verify: VerifyMode::Off,
        ..Default::default()
    })
    .unwrap();
    coord.verify_program(&refuted_program(), 4, None).unwrap();
    let snap = coord.metrics_snapshot();
    assert_eq!(snap.verify_passes + snap.verify_warns + snap.verify_rejects, 0);
}

#[test]
fn session_open_verifies_once_and_serves() {
    // Sessions verify their program at open (counted once), then every
    // session job skips the identical re-check.
    let coord = Coordinator::new(CoordinatorConfig {
        workers: 2,
        geom: ArrayGeometry::new(2, 1),
        verify: VerifyMode::Enforce,
        ..Default::default()
    })
    .unwrap();
    let shape = GemmShape { m: 2, k: 8, n: 2 };
    let mut rng = Xoshiro256::seeded(11);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let session = coord.open_session(shape, 8, weights.clone()).unwrap();
    assert_eq!(coord.metrics_snapshot().verify_passes, 1);
    for id in 0..3u64 {
        let mut a = vec![0i64; shape.m * shape.k];
        rng.fill_signed(&mut a, 8);
        let expect = gemm_ref(shape, &a, &weights);
        let h = coord
            .submit_job(Job::new(id, JobKind::SessionGemm { session, a: a.into() }))
            .unwrap();
        let r = h.wait();
        assert!(r.error.is_none(), "{:?}", r.error);
        assert_eq!(r.output, expect);
    }
    // Still exactly one verification: the admission check is per
    // program, not per request.
    assert_eq!(coord.metrics_snapshot().verify_passes, 1);
}

#[test]
fn pool_verification_tags_the_refuting_backend() {
    // On a heterogeneous pool a finding names the design that refutes
    // it, so a mixed deployment's diagnostics stay actionable.
    let prog = mc(&[Instruction::Load { dst: RfAddr(250), width: 8, buf: BufId(0) }]);
    let pool = [ArchKind::PICASO_F, ArchKind::Custom(CustomDesign::Ccb)];
    let report = verify_on_pool(&prog, GEOM, &pool, false, None);
    assert!(report.has_errors(), "{}", report.render());
    assert!(report.render().contains("[CCB]"), "{}", report.render());
    assert!(!report.render().contains("[PiCaSO"), "{}", report.render());
}

#[test]
fn diagnostics_carry_index_and_rendered_asm() {
    let prog = refuted_program();
    let report = verify(&prog, &overlay_ctx());
    let text = report.render();
    assert!(text.contains("#0"), "{text}");
    assert!(text.contains("STORE"), "{text}");
    assert!(text.contains("r1020"), "{text}");
}

#[test]
fn verify_outcomes_render_in_the_metrics_report() {
    use picaso::verify::VerifyOutcome;
    let m = ServingMetrics::new();
    m.record_verify(None, VerifyOutcome::Pass);
    m.record_verify(None, VerifyOutcome::Reject);
    let text = m.snapshot().render();
    assert!(text.contains("verify"), "{text}");
    assert!(text.contains("rejects=1"), "{text}");
}
