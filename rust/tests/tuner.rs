//! The analytic mapping tuner against the simulators: for unbatched,
//! non-booth runs the per-tile cycle model is exact, predicted totals
//! rank candidate grids exactly as the measured dry-runs do, and the
//! tuner-chosen grid beats the old 1-D Auto column split on a CNN.

use picaso::arch::CustomDesign;
use picaso::compiler::gemm_ref;
use picaso::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, TilePolicy,
};
use picaso::prelude::*;
use picaso::tuner::tile_cost;
use picaso::util::Xoshiro256;
use picaso::workload::ConvWorkload;

const GEOM: ArrayGeometry = ArrayGeometry { rows: 2, cols: 1 };

fn gemm_job(id: u64, shape: GemmShape, seed: u64) -> (Job, Vec<i64>) {
    let mut rng = Xoshiro256::seeded(seed);
    let mut a = vec![0i64; shape.m * shape.k];
    let mut b = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(shape, &a, &b);
    (Job::new(id, JobKind::Gemm { shape, width: 8, a, b }), expect)
}

fn pool_of(kind: ArchKind, workers: usize) -> Coordinator {
    Coordinator::new(CoordinatorConfig {
        workers,
        geom: GEOM,
        kind,
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })
    .unwrap()
}

/// The per-tile model is not an estimate on homogeneous pools: for an
/// unbatched, non-booth run the predicted cycles equal the simulator's
/// measured dry-run charge bit for bit, on overlay and custom designs
/// alike.
#[test]
fn predictions_match_measured_dry_run_cycles_exactly() {
    let kinds = [
        ArchKind::PICASO_F,
        ArchKind::Custom(CustomDesign::CoMeFaA),
        ArchKind::Custom(CustomDesign::Ccb),
        ArchKind::Custom(CustomDesign::AMod),
    ];
    let shapes = [
        GemmShape { m: 2, k: 20, n: 7 },
        GemmShape { m: 4, k: 16, n: 3 },
        GemmShape { m: 2, k: 5, n: 2 },
    ];
    for kind in kinds {
        let coord = pool_of(kind, 1);
        for (i, shape) in shapes.into_iter().enumerate() {
            let (job, expect) = gemm_job(i as u64, shape, 0xBEEF + i as u64);
            let r = coord.submit_job(job).unwrap().wait();
            assert!(r.error.is_none(), "{kind:?} {shape:?}: {:?}", r.error);
            assert_eq!(r.output, expect, "{kind:?} {shape:?}");
            assert_eq!(
                r.stats.cycles,
                tile_cost(shape, 8, kind, GEOM),
                "predicted != measured for {kind:?} {shape:?}"
            );
        }
        coord.shutdown();
    }
}

/// Predicted totals rank candidate grids exactly as the measured
/// rollups do: every grid's measured scattered-job cycle total equals
/// its prediction, so the predicted ordering IS the measured ordering.
#[test]
fn predicted_totals_rank_measured_grids() {
    let coord = pool_of(ArchKind::PICASO_F, 4);
    let pool = coord.worker_kinds().to_vec();
    let shape = GemmShape { m: 4, k: 16, n: 8 };
    let grids = [(1, 1), (1, 2), (1, 4), (2, 1), (2, 2), (4, 2)];
    let mut ranked: Vec<(u64, u64)> = Vec::new();
    for (i, (k_t, n_t)) in grids.into_iter().enumerate() {
        let pred = predict_cycles(shape, 8, TilePolicy::grid(k_t, n_t), &pool, GEOM);
        let (job, expect) = gemm_job(i as u64, shape, 0xFEED);
        let r = coord.submit_job(job.with_shards(TilePolicy::grid(k_t, n_t))).unwrap().wait();
        assert!(r.error.is_none(), "{k_t}x{n_t}: {:?}", r.error);
        assert_eq!(r.output, expect, "{k_t}x{n_t}");
        assert_eq!(r.stats.cycles, pred.total_cycles, "grid {k_t}x{n_t}");
        ranked.push((pred.total_cycles, r.stats.cycles));
    }
    let mut by_pred = ranked.clone();
    by_pred.sort_by_key(|&(p, _)| p);
    let mut by_meas = ranked;
    by_meas.sort_by_key(|&(_, m)| m);
    assert_eq!(by_pred, by_meas, "predicted ranking must match measured ranking");
    coord.shutdown();
}

/// The ISSUE acceptance bar: on a multi-layer CNN the tuner-chosen grid
/// ([`TilePolicy::Auto`]) must cost no more measured dry-run cycles
/// than the old 1-D `Fixed(pool size)` column split — and strictly less
/// on at least one layer. The CNN is shaped so conv layers have few
/// filters (columns) but a deep reduction: the 1-D split clamps to the
/// column count and strands half the pool, while the 2-D grid keeps
/// every region busy.
#[test]
fn tuned_grid_beats_the_one_d_auto_split_on_a_cnn() {
    let coord = pool_of(ArchKind::PICASO_F, 4);
    let pool = coord.worker_kinds().to_vec();
    let items = 2;
    // Two conv layers of a toy CNN: 2ch 5x5 -> 2 filters 2x2 -> 2ch 4x4
    // -> 2 filters 2x2 stride 2. Both lower to GEMMs with n = 2 < pool.
    let convs = [
        ConvWorkload::new(items, 2, 5, 5, 2, 2, 2, 1, 0).unwrap(),
        ConvWorkload::new(items, 2, 4, 4, 2, 2, 2, 2, 0).unwrap(),
    ];
    let mut strictly_better = false;
    for (i, cw) in convs.iter().enumerate() {
        let shape = cw.gemm_shape();
        let tuned = choose_grid(shape, 8, &pool, GEOM);
        let one_d = predict_cycles(shape, 8, TilePolicy::Fixed(pool.len()), &pool, GEOM);
        assert!(
            tuned.critical_cycles <= one_d.critical_cycles,
            "layer {i}: tuned {} vs 1-D {}",
            tuned.critical_cycles,
            one_d.critical_cycles
        );
        strictly_better |= tuned.critical_cycles < one_d.critical_cycles;
        // Anchor both predictions to the machines: run the layer's
        // im2col GEMM under each policy and check the measured rollup
        // equals the predicted total, cycle for cycle.
        let mut rng = Xoshiro256::seeded(0xC0DE + i as u64);
        let mut input = vec![0i64; items * cw.input_len_per_item()];
        let mut filters = vec![0i64; cw.k * cw.r * cw.s * cw.c];
        rng.fill_signed(&mut input, 8);
        rng.fill_signed(&mut filters, 8);
        let a = cw.im2col(items, &input).unwrap();
        let b = cw.lower_weights(&filters).unwrap();
        let expect = cw.conv_ref(items, &input, &filters).unwrap();
        assert_eq!(expect, gemm_ref(shape, &a, &b));
        for (policy, pred) in
            [(TilePolicy::Auto, tuned), (TilePolicy::Fixed(pool.len()), one_d)]
        {
            let job = Job::new(
                i as u64,
                JobKind::Gemm { shape, width: 8, a: a.clone(), b: b.clone() },
            )
            .with_shards(policy);
            let r = coord.submit_job(job).unwrap().wait();
            assert!(r.error.is_none(), "layer {i} {policy:?}: {:?}", r.error);
            assert_eq!(r.output, expect, "layer {i} {policy:?}");
            assert_eq!(
                r.stats.cycles, pred.total_cycles,
                "layer {i} {policy:?}: measured rollup must equal the prediction"
            );
        }
    }
    assert!(strictly_better, "the 2-D grid must strictly win on at least one layer");
    coord.shutdown();
}
