#!/usr/bin/env bash
# CI gate for the PiCaSO reproduction. Mirrors the tier-1 verify from
# ROADMAP.md and adds the documentation and formatting gates.
#
#   ./ci.sh            run everything
#   ./ci.sh fast       build + tests only (tier-1)
set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n=== %s ===\n' "$*"; }

step "tier-1: cargo build --release"
cargo build --release

step "tier-1: cargo test -q"
cargo test -q

# The 2-D tiling acceptance suite, run by name so a tiling regression is
# unmissable in the log even when the full suite is noisy.
step "tier-1: cargo test --test tiling -q"
cargo test --test tiling -q

# The convolution + auto-tuner acceptance suites, likewise by name: conv
# bit-exactness across pools/policies and the tuner's cycle-exactness
# and tuned-beats-1-D acceptance bar.
step "tier-1: cargo test --test workload --test tuner -q"
cargo test --test workload --test tuner -q

# The static-verifier acceptance suite, by name: one negative test per
# defect class, the compiler clean-sweep, and the enforce-at-admission
# contract (rejection before any queue slot is debited).
step "tier-1: cargo test --test verify -q"
cargo test --test verify -q

# The concurrency stress suite, by name: seeded many-producer /
# many-worker load over mixed backend-class pools on both queue layouts
# — no lost wakeups, no class starvation, reservation atomicity, and
# bit-exact outputs under sustained contention.
step "tier-1: cargo test --test stress -q"
cargo test --test stress -q

if [ "${1:-}" = "fast" ]; then
    echo "fast mode: skipping doc/fmt/bench-compile gates"
    exit 0
fi

# Release profile so the artifacts are shared with the tier-1 build and
# the bench-compile step below instead of paying a second debug compile.
step "examples: cargo build --release --examples"
cargo build --release --examples

step "doctests: cargo test --doc -q"
cargo test --doc -q

# ---------------------------------------------------------------------
# Bench smokes + baseline regression gate.
#
# Each smoke writes a fresh JSON next to the committed baseline
# (BENCH_*.json). Cycle-domain keys — simulated work, machine- and
# load-independent — are compared against the baseline within
# BENCH_TOL_PCT percent (default 10); wall-clock keys (throughput,
# latency percentiles) are recorded for the review diff but not gated,
# since they track the host, not the code. A missing baseline is seeded
# from the fresh run: commit it so later runs have something to gate on.
BENCH_TOL_PCT="${BENCH_TOL_PCT:-10}"

bench_key() { # file key -> numeric value (first match)
    sed -n "s/.*\"$2\": *\(-\{0,1\}[0-9][0-9.]*\).*/\1/p" "$1" | head -n 1
}

bench_gate() { # name baseline fresh key...
    local name="$1" base="$2" fresh="$3" fail=0 key b f
    shift 3
    if [ ! -s "$base" ]; then
        cp "$fresh" "$base"
        echo "$name: no committed baseline — seeded $base from this run (commit it)"
        return 0
    fi
    for key in "$@"; do
        b="$(bench_key "$base" "$key")"
        f="$(bench_key "$fresh" "$key")"
        if [ -z "$b" ] || [ -z "$f" ]; then
            echo "$name: key '$key' missing (baseline='$b' fresh='$f')"
            fail=1
            continue
        fi
        if ! awk -v b="$b" -v f="$f" -v t="$BENCH_TOL_PCT" 'BEGIN {
            d = (b == 0) ? (f == 0 ? 0 : 1e9) : (f - b) / b * 100;
            if (d < 0) d = -d;
            exit (d > t) ? 1 : 0;
        }'; then
            echo "$name: '$key' drifted beyond ${BENCH_TOL_PCT}%: baseline $b, fresh $f"
            fail=1
        else
            echo "$name: '$key' within tolerance (baseline $b, fresh $f)"
        fi
    done
    return "$fail"
}

step "bench smoke: examples/serve headless -> BENCH_serve.fresh.json"
SERVE_BENCH_JSON=BENCH_serve.fresh.json \
    cargo run --release --example serve -- 48 2 picaso >/dev/null
test -s BENCH_serve.fresh.json || { echo "BENCH_serve.fresh.json missing or empty"; exit 1; }
cat BENCH_serve.fresh.json

step "bench gate: BENCH_serve.json (cycle-domain keys, ±${BENCH_TOL_PCT}%)"
bench_gate "serve" BENCH_serve.json BENCH_serve.fresh.json pim_cycles_per_job \
    || { echo "serve bench gate failed (rerun and commit BENCH_serve.json if intended)"; exit 1; }

# ---------------------------------------------------------------------
# Trace gate: rerun the serve smoke with the span journal attached,
# validate the journal through the summarizer (`picaso trace` exits
# non-zero on malformed JSON, unclosed spans, or children escaping
# their parents), and check tracing didn't tank throughput — traced
# jobs/s must stay within tolerance of the untraced run just above
# (wall-clock, so BENCH_TRACE_TOL_PCT can widen it on noisy hosts).
step "trace smoke: examples/serve --trace -> BENCH_serve.trace.json"
SERVE_BENCH_JSON=BENCH_serve.traced.json \
    cargo run --release --example serve -- 48 2 picaso --trace=BENCH_serve.trace.json >/dev/null
test -s BENCH_serve.trace.json || { echo "BENCH_serve.trace.json missing or empty"; exit 1; }
test -s BENCH_serve.traced.json || { echo "BENCH_serve.traced.json missing or empty"; exit 1; }

step "trace gate: picaso trace BENCH_serve.trace.json (journal must validate)"
cargo run --release -- trace BENCH_serve.trace.json \
    || { echo "trace gate failed: span journal is malformed or ill-formed"; exit 1; }

step "trace gate: traced throughput vs untraced (jobs_per_sec, ±${BENCH_TRACE_TOL_PCT:-$BENCH_TOL_PCT}%)"
BENCH_TOL_PCT="${BENCH_TRACE_TOL_PCT:-$BENCH_TOL_PCT}" \
    bench_gate "serve-traced" BENCH_serve.fresh.json BENCH_serve.traced.json jobs_per_sec \
    || { echo "trace overhead gate failed: tracing slowed serving beyond tolerance"; exit 1; }
rm -f BENCH_serve.trace.json BENCH_serve.traced.json

step "bench smoke: examples/infer headless -> BENCH_infer.fresh.json"
INFER_BENCH_JSON=BENCH_infer.fresh.json \
    cargo run --release --example infer -- 24 2 picaso >/dev/null
test -s BENCH_infer.fresh.json || { echo "BENCH_infer.fresh.json missing or empty"; exit 1; }
cat BENCH_infer.fresh.json

step "bench gate: BENCH_infer.json (cycle-domain keys, ±${BENCH_TOL_PCT}%)"
bench_gate "infer" BENCH_infer.json BENCH_infer.fresh.json \
    sequential_makespan_cycles pipelined_makespan_cycles makespan_speedup \
    || { echo "infer bench gate failed (rerun and commit BENCH_infer.json if intended)"; exit 1; }

step "bench smoke: examples/conv headless -> BENCH_conv.fresh.json"
CONV_BENCH_JSON=BENCH_conv.fresh.json \
    cargo run --release --example conv -- 8 2 picaso >/dev/null
test -s BENCH_conv.fresh.json || { echo "BENCH_conv.fresh.json missing or empty"; exit 1; }
cat BENCH_conv.fresh.json

step "bench gate: BENCH_conv.json (cycle-domain keys, ±${BENCH_TOL_PCT}%)"
bench_gate "conv" BENCH_conv.json BENCH_conv.fresh.json \
    tuned_total_cycles fixed_total_cycles pipelined_makespan_cycles \
    || { echo "conv bench gate failed (rerun and commit BENCH_conv.json if intended)"; exit 1; }

step "bench smoke: examples/bench_sched open-loop -> BENCH_sched.fresh.json"
SCHED_BENCH_JSON=BENCH_sched.fresh.json \
    cargo run --release --example bench_sched -- 600 4 4 >/dev/null
test -s BENCH_sched.fresh.json || { echo "BENCH_sched.fresh.json missing or empty"; exit 1; }
cat BENCH_sched.fresh.json

# The scheduler bench is pure wall-clock (there is no cycle domain in
# queue contention), so its keys gate at a wider tolerance than the
# cycle-domain benches — enough to catch a lost-wakeup stall or a
# contention regression, loose enough to ride out host noise.
step "bench gate: BENCH_sched.json (wall-clock keys, ±${BENCH_SCHED_TOL_PCT:-50}%)"
BENCH_TOL_PCT="${BENCH_SCHED_TOL_PCT:-50}" \
    bench_gate "sched" BENCH_sched.json BENCH_sched.fresh.json \
    jobs_per_sec queue_lock_wait_ns_p95 \
    || { echo "sched bench gate failed (rerun and commit BENCH_sched.json if intended)"; exit 1; }

step "compile benches + examples"
cargo build --release --benches --examples

# Hard gate: the crate carries #![forbid(unsafe_code)] and must stay
# clippy-clean at -D warnings. No soft-skip — a toolchain that can run
# this script at all (cargo exists) must provide the lint gate too.
step "lint gate: cargo clippy --all-targets -- -D warnings"
cargo clippy --all-targets --quiet -- -D warnings

step "doc gate: cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

step "format gate: cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt not installed — skipping (install with: rustup component add rustfmt)"
fi

echo
echo "ci.sh: all gates passed"
