#!/usr/bin/env bash
# CI gate for the PiCaSO reproduction. Mirrors the tier-1 verify from
# ROADMAP.md and adds the documentation and formatting gates.
#
#   ./ci.sh            run everything
#   ./ci.sh fast       build + tests only (tier-1)
set -euo pipefail
cd "$(dirname "$0")"

step() { printf '\n=== %s ===\n' "$*"; }

step "tier-1: cargo build --release"
cargo build --release

step "tier-1: cargo test -q"
cargo test -q

if [ "${1:-}" = "fast" ]; then
    echo "fast mode: skipping doc/fmt/bench-compile gates"
    exit 0
fi

# Release profile so the artifacts are shared with the tier-1 build and
# the bench-compile step below instead of paying a second debug compile.
step "examples: cargo build --release --examples"
cargo build --release --examples

step "doctests: cargo test --doc -q"
cargo test --doc -q

# Perf trajectory per PR: run the serving example headless and persist
# its headline numbers (p50/p95 queue + end-to-end latency, throughput,
# retry/shed counts) so regressions show up in review as a JSON diff.
step "bench smoke: examples/serve headless -> BENCH_serve.json"
SERVE_BENCH_JSON=BENCH_serve.json cargo run --release --example serve -- 48 2 picaso >/dev/null
test -s BENCH_serve.json || { echo "BENCH_serve.json missing or empty"; exit 1; }
echo "BENCH_serve.json:"
cat BENCH_serve.json

# Model-graph executor trajectory: pipelined multi-layer inference with
# per-layer + end-to-end latency and the cycle-makespan speedup.
step "bench smoke: examples/infer headless -> BENCH_infer.json"
INFER_BENCH_JSON=BENCH_infer.json cargo run --release --example infer -- 24 2 picaso >/dev/null
test -s BENCH_infer.json || { echo "BENCH_infer.json missing or empty"; exit 1; }
echo "BENCH_infer.json:"
cat BENCH_infer.json

step "compile benches + examples"
cargo build --release --benches --examples

step "lint gate: cargo clippy --all-targets -- -D warnings"
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets --quiet -- -D warnings
else
    echo "clippy not installed — skipping (install with: rustup component add clippy)"
fi

step "doc gate: cargo doc --no-deps (warnings denied)"
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet

step "format gate: cargo fmt --check"
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --check
else
    echo "rustfmt not installed — skipping (install with: rustup component add rustfmt)"
fi

echo
echo "ci.sh: all gates passed"
