//! Multi-layer MLP inference through the model-graph executor — the
//! deployment shape the paper's ML motivation actually implies: a whole
//! network mapped onto the serving stack, not a stream of isolated
//! GEMMs.
//!
//! The drill builds a 48→32→24→10 int8 MLP with the paper's
//! BNN-flavoured `sign` activation (binarized hidden activations keep
//! every layer's operands in range with zero requantization logic),
//! compiles it to pinned per-layer sessions, and serves a request batch
//! two ways over the same pool:
//!
//! 1. **pipelined** — each request's next layer is submitted the moment
//!    its previous layer gathers, so layer `L` of request `i` overlaps
//!    layer `L-1` of request `i+1` across the worker regions;
//! 2. **layer-barrier** — every request finishes layer `L` before any
//!    request starts `L+1` (the sequential baseline).
//!
//! Every output is verified bit-exact against the scalar i64 reference
//! in both modes. The report shows per-layer cycles/retries/occupancy,
//! per-layer pim-time at each design's clock on the U55 (via
//! `design_clock_hz`), end-to-end p50/p95, and the deterministic
//! cycle-makespan comparison (sequential vs pipelined).
//!
//! ```bash
//! cargo run --release --example infer -- [requests] [workers] [backend] [--trace=<p>]
//! ```
//!
//! `--trace=<path>` attaches a span journal (model-request roots with
//! per-layer child spans) and writes it as Chrome trace-event JSON —
//! load it in Perfetto or summarize it with `picaso trace <path>`.
//!
//! Set `INFER_BENCH_JSON=<path>` to persist the headline numbers (per
//! layer + end-to-end latency, throughput, makespans) for the per-PR
//! perf trajectory tracked by `ci.sh`'s bench-smoke step.

use picaso::analytic::design_clock_hz;
use picaso::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, RegionSpec};
use picaso::device::Device;
use picaso::model::{CompileOptions, CompiledModel, ExecMode, GraphExecutor};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::time::Duration;

const DIMS: [usize; 4] = [48, 32, 24, 10];
const WIDTH: u16 = 8;

fn main() -> picaso::Result<()> {
    // `--trace=<path>` can appear anywhere; the remaining tokens are the
    // positional [requests] [workers] [backend].
    let (trace_path, argv): (Option<String>, Vec<String>) = {
        let mut trace = None;
        let mut rest = Vec::new();
        for tok in std::env::args().skip(1) {
            match tok.strip_prefix("--trace=") {
                Some(p) => trace = Some(p.to_string()),
                None => rest.push(tok),
            }
        }
        (trace, rest)
    };
    let requests: usize = argv.first().and_then(|s| s.parse().ok()).unwrap_or(32);
    let workers: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let backend_name: String = argv.get(2).cloned().unwrap_or_else(|| "picaso".into());
    let tracer = trace_path.as_ref().map(|_| std::sync::Arc::new(Tracer::new(workers)));

    let (kind, regions): (ArchKind, Vec<RegionSpec>) = if backend_name == "mixed" {
        (ArchKind::PICASO_F, RegionSpec::mixed_pool(workers))
    } else {
        (picaso::cli::parse_backend(&backend_name)?, Vec::new())
    };
    let geom = ArrayGeometry::new(8, 4);
    let device = Device::by_id("U55").expect("U55 is in the device database");

    println!(
        "model-graph inference: {}x{}x{}x{} int8 MLP (sign/BNN hidden activations), \
         {requests} requests on {workers} {backend_name} workers ({}x{}-block regions)",
        DIMS[0], DIMS[1], DIMS[2], DIMS[3], geom.rows, geom.cols,
    );

    let graph = picaso::cli::build_mlp(&DIMS, WIDTH, "sign", 0xD161)?;
    let mut rng = Xoshiro256::seeded(0x1F2E);
    let mut inputs = Vec::with_capacity(requests);
    for _ in 0..requests {
        let mut a = vec![0i64; DIMS[0]];
        rng.fill_signed(&mut a, WIDTH as u32);
        inputs.push(a);
    }
    let expects: Vec<Vec<i64>> = inputs
        .iter()
        .map(|a| graph.forward_ref(a, 1))
        .collect::<picaso::Result<_>>()?;

    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        kind,
        regions,
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_micros(200) },
        trace: tracer.clone(),
        ..Default::default()
    })?;
    let model = CompiledModel::compile(&coord, graph, CompileOptions::default())?;
    let exec = GraphExecutor::new(&coord, &model);

    // ------------------------------------------------ phase 1: pipelined
    coord.serving_metrics().reset_window();
    let pipe = exec.infer_batch(&inputs, ExecMode::Pipelined)?;
    let pipe_bad = pipe.outputs.iter().zip(&expects).filter(|(g, w)| g != w).count();
    assert_eq!(pipe_bad, 0, "pipelined outputs must match the scalar reference");

    println!("\n--- pipelined (layer L of request i overlaps layer L-1 of request i+1) ---");
    println!(
        "{:>6} {:>10} {:>6} {:>12} {:>8} {:>10} {:>14}",
        "layer", "shape", "jobs", "cycles", "retries", "busy us", "pim/job"
    );
    for (idx, cl) in model.layers().iter().enumerate() {
        let lr = &pipe.per_layer[idx];
        let lspec = &model.graph().layers()[idx];
        let freq = design_clock_hz(cl.kind, device);
        let per_job = if lr.jobs > 0 { lr.cycles as f64 / lr.jobs as f64 } else { 0.0 };
        println!(
            "{:>6} {:>10} {:>6} {:>12} {:>8} {:>10.0} {:>14}",
            idx,
            format!("{}->{}", lspec.k, lspec.n),
            lr.jobs,
            lr.cycles,
            lr.retries,
            lr.busy_us,
            format!(
                "{} @{}",
                picaso::util::fmt_ns(per_job / freq * 1e9),
                picaso::util::fmt_freq(freq)
            ),
        );
    }
    let (p50, p95) = pipe.request_latency_p50_p95();
    println!(
        "end-to-end p50={p50:.0}us p95={p95:.0}us  throughput={:.1} req/s (wall {:.1}ms)",
        requests as f64 / (pipe.wall_us / 1e6).max(1e-9),
        pipe.wall_us / 1e3,
    );

    // ---------------------------------------------- phase 2: the barrier
    let barrier = exec.infer_batch(&inputs, ExecMode::LayerBarrier)?;
    let barrier_bad = barrier.outputs.iter().zip(&expects).filter(|(g, w)| g != w).count();
    assert_eq!(barrier_bad, 0, "barrier outputs must match the scalar reference");
    assert_eq!(pipe.outputs, barrier.outputs, "modes must agree bit-for-bit");
    let (bp50, bp95) = barrier.request_latency_p50_p95();
    println!(
        "\n--- layer-barrier baseline: p50={bp50:.0}us p95={bp95:.0}us wall {:.1}ms ---",
        barrier.wall_us / 1e3
    );

    // ------------------------------------------- the deterministic model
    let est = model.pipeline_estimate(requests);
    let hz = model.min_clock_hz(device);
    let (seq_ns, pipe_ns) = pipe.makespan_ns(hz);
    println!(
        "\ncycle-makespan model (measured per-layer sums): sequential {:.0} ({}) vs \
         pipelined {:.0} ({}) => {:.2}x  (compile-time estimate {:.2}x, {} at {})",
        pipe.sequential_makespan_cycles,
        picaso::util::fmt_ns(seq_ns),
        pipe.pipelined_makespan_cycles,
        picaso::util::fmt_ns(pipe_ns),
        pipe.pipeline_speedup(),
        est.speedup(),
        device.id,
        picaso::util::fmt_freq(hz),
    );
    println!("\nserving metrics:\n{}", coord.metrics_snapshot().render());

    // ------------------------------------------------ bench JSON (CI)
    if let Ok(path) = std::env::var("INFER_BENCH_JSON") {
        if !path.is_empty() {
            let per_layer_cycles: Vec<String> =
                pipe.per_layer.iter().map(|l| l.cycles.to_string()).collect();
            let json = format!(
                "{{\n  \"requests\": {},\n  \"workers\": {},\n  \"backend\": \"{}\",\n  \
                 \"layers\": {},\n  \"e2e_p50_us\": {:.3},\n  \"e2e_p95_us\": {:.3},\n  \
                 \"throughput_req_s\": {:.3},\n  \"barrier_wall_us\": {:.3},\n  \
                 \"pipelined_wall_us\": {:.3},\n  \"per_layer_cycles\": [{}],\n  \
                 \"sequential_makespan_cycles\": {:.1},\n  \
                 \"pipelined_makespan_cycles\": {:.1},\n  \"makespan_speedup\": {:.3}\n}}\n",
                requests,
                workers,
                backend_name,
                model.layers().len(),
                p50,
                p95,
                requests as f64 / (pipe.wall_us / 1e6).max(1e-9),
                barrier.wall_us,
                pipe.wall_us,
                per_layer_cycles.join(", "),
                pipe.sequential_makespan_cycles,
                pipe.pipelined_makespan_cycles,
                pipe.pipeline_speedup(),
            );
            std::fs::write(&path, json)?;
            println!("\nwrote bench snapshot to {path}");
        }
    }

    model.close(&coord);
    coord.shutdown();

    // ------------------------------------------------ trace export
    if let (Some(tr), Some(path)) = (&tracer, &trace_path) {
        TraceSink::write(tr, std::path::Path::new(path))?;
        println!(
            "wrote {} spans (dropped {}) to {path} — summarize with `picaso trace {path}`",
            tr.events().len(),
            tr.dropped(),
        );
    }

    println!("\ninfer OK — all {requests} requests bit-exact in both modes");
    Ok(())
}
