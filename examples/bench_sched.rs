//! Open-loop scheduler stress: raw queue throughput under producer ×
//! worker contention, with the per-class sharded lanes measured against
//! the single-lock baseline in the same process.
//!
//! Unlike `examples/serve.rs` (closed loop: each client waits for its
//! result before submitting the next), producers here submit their whole
//! quota as fast as admission allows and only then wait on the handles —
//! the queue runs saturated, so lock contention, pop scan cost and
//! wakeup routing dominate instead of array execute time. Micro-batching
//! is disabled for the same reason: one pop per job maximizes scheduler
//! pressure.
//!
//! The pool is heterogeneous (overlay + CoMeFa-A regions via
//! [`RegionSpec::mixed_pool`]) and jobs alternate class tags
//! (overlay-pinned / custom-pinned / untagged), so the per-class lanes
//! actually partition the load. Both [`QueueSharding`] modes run over
//! the identical workload:
//!
//! 1. **single** — one shared sub-queue, the pre-sharding layout;
//! 2. **per-class** — one lane per backend class plus the shared lane.
//!
//! Every output is checked against `gemm_ref`, so the speedup is at
//! equal correctness. The perf lane of the metrics snapshot supplies the
//! trajectory numbers: queue-lock wait p95, tickets scanned per pop,
//! scratch-pool hit rate, and fresh bytes allocated per job.
//!
//! ```bash
//! cargo run --release --example bench_sched -- [jobs] [producers] [workers]
//! ```
//!
//! Set `SCHED_BENCH_JSON=<path>` to write the headline numbers
//! (`jobs_per_sec`, `queue_lock_wait_ns_p95`, both modes + speedup) as a
//! JSON object — the scheduler leg of the per-PR perf trajectory tracked
//! by `ci.sh`'s bench-smoke step.

use picaso::arch::CustomDesign;
use picaso::compiler::{gemm_ref, GemmShape};
use picaso::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, QueueSharding, RegionSpec,
    SchedulerConfig,
};
use picaso::metrics::MetricsSnapshot;
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::sync::Arc;

/// One open-loop phase: `producers` threads each submit their share of
/// `jobs` back-to-back (blocking only on queue admission), then wait on
/// every handle and verify against the reference. Returns the metrics
/// snapshot and the miscompare/failure count.
fn run_open_loop(
    sharding: QueueSharding,
    jobs: usize,
    producers: usize,
    workers: usize,
) -> picaso::Result<(MetricsSnapshot, usize)> {
    let geom = ArrayGeometry::new(4, 1);
    let shape = GemmShape { m: 2, k: 16, n: 2 };
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        kind: ArchKind::PICASO_F,
        regions: RegionSpec::mixed_pool(workers),
        // One pop per job: scheduler pressure, not batching, is under test.
        batch: BatchPolicy::disabled(),
        scheduler: SchedulerConfig {
            backpressure: Backpressure::Block,
            sharding,
            ..Default::default()
        },
        ..Default::default()
    })?);
    let mut weights = vec![0i64; shape.k * shape.n];
    Xoshiro256::seeded(0xBEEF).fill_signed(&mut weights, 8);
    let sid = coord.open_session(shape, 8, weights.clone())?;
    let weights = Arc::new(weights);
    coord.serving_metrics().reset_window();

    // The class rotation: untagged (any region), overlay-pinned,
    // custom-pinned — all three lanes of the sharded queue see load.
    let tags = [
        None,
        Some(BackendClass::Overlay),
        Some(BackendClass::Custom(CustomDesign::CoMeFaA)),
    ];
    let mut threads = Vec::new();
    for p in 0..producers {
        let quota = jobs / producers + usize::from(p < jobs % producers);
        let coord = Arc::clone(&coord);
        let weights = Arc::clone(&weights);
        threads.push(std::thread::spawn(move || -> picaso::Result<usize> {
            let mut rng = Xoshiro256::seeded(0x0BE7 + p as u64);
            // Open loop: admit everything first, wait afterwards.
            let mut inflight = Vec::with_capacity(quota);
            for j in 0..quota {
                let id = (p * 1_000_000 + j) as u64;
                let mut a = vec![0i64; shape.m * shape.k];
                rng.fill_signed(&mut a, 8);
                let expect = gemm_ref(shape, &a, &weights);
                // Alternate ad-hoc and session-backed jobs so both the
                // plain-GEMM and pinned-weight serving paths run hot.
                let kind = if j % 2 == 0 {
                    JobKind::Gemm { shape, width: 8, a, b: weights.as_ref().clone() }
                } else {
                    JobKind::SessionGemm { session: sid, a: a.into() }
                };
                let mut job = Job::new(id, kind);
                job.backend = tags[j % tags.len()];
                inflight.push((coord.submit_job(job)?, expect));
            }
            let mut bad = 0;
            for (handle, expect) in inflight {
                let r = handle.wait();
                if r.error.is_some() || r.output != expect {
                    bad += 1;
                }
            }
            Ok(bad)
        }));
    }
    let mut bad = 0;
    for t in threads {
        bad += t.join().expect("producer panicked")?;
    }
    let snap = coord.metrics_snapshot();
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }
    Ok((snap, bad))
}

fn perf_line(name: &str, snap: &MetricsSnapshot) {
    println!(
        "  {:<10} {:>10.1} jobs/s  lock_waits={:<6} lock_wait_p95={:>7.0}ns \
         scanned/pop={:<5.2} pool_hit={:>3.0}% alloc/job={:.0}B",
        name,
        snap.jobs_per_sec(),
        snap.lock_waits,
        snap.lock_wait_ns.p95,
        snap.scanned_per_pop(),
        snap.pool_hit_rate() * 100.0,
        snap.bytes_per_job(),
    );
}

fn main() -> picaso::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let arg = |i: usize, default: usize| -> usize {
        args.get(i).and_then(|s| s.parse().ok()).unwrap_or(default)
    };
    let jobs = arg(0, 600);
    let producers = arg(1, 4).max(1);
    let workers = arg(2, 4).max(2);
    println!(
        "open-loop scheduler stress: {jobs} jobs, {producers} producers, {workers} workers \
         (mixed overlay + CoMeFa-A pool, micro-batching off)\n"
    );

    // Same workload, both queue layouts. Single first so the per-class
    // numbers land on a warmed process (allocator, page cache) — the
    // conservative ordering for the speedup claim.
    let (single, bad_single) = run_open_loop(QueueSharding::Single, jobs, producers, workers)?;
    let (sharded, bad_sharded) = run_open_loop(QueueSharding::PerClass, jobs, producers, workers)?;
    assert_eq!(bad_single, 0, "single-lane outputs must match gemm_ref");
    assert_eq!(bad_sharded, 0, "per-class outputs must match gemm_ref");
    assert_eq!(single.jobs as usize, jobs, "single lane served every job");
    assert_eq!(sharded.jobs as usize, jobs, "per-class lanes served every job");

    println!("--- queue layout comparison ({jobs} jobs, bit-exact in both) ---");
    perf_line("single", &single);
    perf_line("per-class", &sharded);
    let speedup = if single.jobs_per_sec() > 0.0 {
        sharded.jobs_per_sec() / single.jobs_per_sec()
    } else {
        0.0
    };
    println!(
        "\nper-class lanes vs single lock: {speedup:.2}x jobs/s \
         (lock_wait_p95 {:.0}ns -> {:.0}ns)",
        single.lock_wait_ns.p95, sharded.lock_wait_ns.p95,
    );

    if let Ok(path) = std::env::var("SCHED_BENCH_JSON") {
        if !path.is_empty() {
            let json = format!(
                "{{\n  \"jobs\": {},\n  \"producers\": {},\n  \"workers\": {},\n  \
                 \"jobs_per_sec\": {:.3},\n  \"queue_lock_wait_ns_p95\": {:.3},\n  \
                 \"scanned_per_pop\": {:.3},\n  \"pool_hit_rate\": {:.4},\n  \
                 \"alloc_bytes_per_job\": {:.1},\n  \
                 \"jobs_per_sec_single\": {:.3},\n  \
                 \"queue_lock_wait_ns_p95_single\": {:.3},\n  \
                 \"sharding_speedup\": {:.3}\n}}\n",
                jobs,
                producers,
                workers,
                sharded.jobs_per_sec(),
                sharded.lock_wait_ns.p95,
                sharded.scanned_per_pop(),
                sharded.pool_hit_rate(),
                sharded.bytes_per_job(),
                single.jobs_per_sec(),
                single.lock_wait_ns.p95,
                speedup,
            );
            std::fs::write(&path, json)?;
            println!("\nwrote bench snapshot to {path}");
        }
    }

    println!("\nbench_sched OK");
    Ok(())
}
