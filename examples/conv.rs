//! Convolution serving through the model-graph executor, with the
//! analytic mapping tuner picking per-layer tile grids.
//!
//! The drill builds a small int8 CNN (`cnn:` spec: conv -> conv ->
//! dense head with sign activations), lowers every conv via im2col to
//! the GEMM the PIM arrays actually run, and serves a request batch
//! twice over the same pool:
//!
//! 1. **fixed 1-D** — every layer column-split across the pool
//!    (`TilePolicy::Fixed(workers)`, the pre-tuner `Auto` behaviour);
//! 2. **tuned** — [`TuneMode::Auto`]: the tuner searches `k_tiles ×
//!    n_tiles` grids per layer and submits each layer with its pick.
//!
//! Every output is verified bit-exact against the scalar direct
//! convolution reference in both configurations, and the report
//! compares per-layer measured cycles, the chosen grids with their
//! predictions, and the cycle-denominated makespans (plus wall time at
//! the design clock on the U55).
//!
//! ```bash
//! cargo run --release --example conv -- [requests] [workers] [backend]
//! ```
//!
//! Set `CONV_BENCH_JSON=<path>` to persist the headline cycle-domain
//! numbers for the per-PR perf trajectory tracked by `ci.sh`'s
//! bench-smoke step.

use picaso::coordinator::{BatchPolicy, Coordinator, CoordinatorConfig, RegionSpec, TilePolicy};
use picaso::device::Device;
use picaso::model::{CompileOptions, CompiledModel, ExecMode, GraphExecutor, TuneMode};
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::time::Duration;

const SPEC: &str = "cnn:2@8x8,4@3x3,4@2x2s2,10";
const WIDTH: u16 = 8;

fn main() -> picaso::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let requests: usize = argv.first().and_then(|s| s.parse().ok()).unwrap_or(8);
    let workers: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let backend_name: String = argv.get(2).cloned().unwrap_or_else(|| "picaso".into());

    let (kind, regions): (ArchKind, Vec<RegionSpec>) = if backend_name == "mixed" {
        (ArchKind::PICASO_F, RegionSpec::mixed_pool(workers))
    } else {
        (picaso::cli::parse_backend(&backend_name)?, Vec::new())
    };
    let geom = ArrayGeometry::new(8, 4);
    let device = Device::by_id("U55").expect("U55 is in the device database");

    println!(
        "conv serving: {SPEC} int8 CNN (im2col-lowered), {requests} requests on \
         {workers} {backend_name} workers ({}x{}-block regions)",
        geom.rows, geom.cols,
    );

    let mut rng = Xoshiro256::seeded(0xC4A7);
    let probe = picaso::cli::build_cnn(SPEC, WIDTH, "sign", 0xC0DE)?;
    let mut inputs = Vec::with_capacity(requests);
    for _ in 0..requests {
        let mut a = vec![0i64; probe.input_dim()];
        rng.fill_signed(&mut a, WIDTH as u32);
        inputs.push(a);
    }
    let expects: Vec<Vec<i64>> = inputs
        .iter()
        .map(|a| probe.forward_ref(a, 1))
        .collect::<picaso::Result<_>>()?;

    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        kind,
        regions,
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_micros(200) },
        ..Default::default()
    })?;

    // One pass per tiling configuration over the same pool.
    let mut results = Vec::new();
    for (label, tune) in [
        ("fixed-1d", TuneMode::Fixed(TilePolicy::Fixed(workers))),
        ("tuned", TuneMode::Auto),
    ] {
        let graph = picaso::cli::build_cnn(SPEC, WIDTH, "sign", 0xC0DE)?;
        // Reset before compile: TuneMode::Auto records its per-layer
        // grid picks into the tuner metrics lane at compile time.
        coord.serving_metrics().reset_window();
        let model = CompiledModel::compile(
            &coord,
            graph,
            CompileOptions { tune, ..Default::default() },
        )?;
        let exec = GraphExecutor::new(&coord, &model);
        let report = exec.infer_batch(&inputs, ExecMode::Pipelined)?;
        let bad = report.outputs.iter().zip(&expects).filter(|(g, w)| g != w).count();
        assert_eq!(bad, 0, "{label}: outputs must match the scalar direct convolution");

        println!("\n--- {label} ---");
        println!(
            "{:>6} {:>12} {:>6} {:>12} {:>10} {:>16}",
            "layer", "shape", "jobs", "cycles", "policy", "tuner"
        );
        for (idx, cl) in model.layers().iter().enumerate() {
            let lr = &report.per_layer[idx];
            let lspec = &model.graph().layers()[idx];
            let tuner = match &cl.predicted {
                Some(p) => format!("{}x{} {}cyc", p.k_tiles, p.n_tiles, p.total_cycles),
                None => "-".into(),
            };
            println!(
                "{:>6} {:>12} {:>6} {:>12} {:>10} {:>16}",
                idx,
                format!("{}->{}", lspec.k, lspec.n),
                lr.jobs,
                lr.cycles,
                format!("{:?}", cl.shards).chars().take(10).collect::<String>(),
                tuner,
            );
        }
        let hz = model.min_clock_hz(device);
        let (seq_ns, pipe_ns) = report.makespan_ns(hz);
        println!(
            "makespan: sequential {:.0} cycles ({}) vs pipelined {:.0} cycles ({}) => \
             {:.2}x ({} at {})",
            report.sequential_makespan_cycles,
            picaso::util::fmt_ns(seq_ns),
            report.pipelined_makespan_cycles,
            picaso::util::fmt_ns(pipe_ns),
            report.pipeline_speedup(),
            device.id,
            picaso::util::fmt_freq(hz),
        );
        let cycles: Vec<u64> = report.per_layer.iter().map(|l| l.cycles).collect();
        model.close(&coord);
        results.push((label, cycles, report));
    }
    println!("\nserving metrics (tuned window):\n{}", coord.metrics_snapshot().render());

    let (_, fixed_cycles, fixed) = &results[0];
    let (_, tuned_cycles, tuned) = &results[1];
    let fixed_total: u64 = fixed_cycles.iter().sum();
    let tuned_total: u64 = tuned_cycles.iter().sum();
    println!(
        "\ntuned vs fixed-1d: {tuned_total} vs {fixed_total} total pim-cycles \
         ({:.2}x), pipelined makespan {:.0} vs {:.0}",
        fixed_total as f64 / tuned_total.max(1) as f64,
        tuned.pipelined_makespan_cycles,
        fixed.pipelined_makespan_cycles,
    );

    // ------------------------------------------------ bench JSON (CI)
    if let Ok(path) = std::env::var("CONV_BENCH_JSON") {
        if !path.is_empty() {
            let per_layer: Vec<String> = tuned_cycles.iter().map(u64::to_string).collect();
            let json = format!(
                "{{\n  \"requests\": {},\n  \"workers\": {},\n  \"backend\": \"{}\",\n  \
                 \"layers\": {},\n  \"tuned_total_cycles\": {},\n  \
                 \"fixed_total_cycles\": {},\n  \"per_layer_cycles\": [{}],\n  \
                 \"sequential_makespan_cycles\": {:.1},\n  \
                 \"pipelined_makespan_cycles\": {:.1},\n  \"makespan_speedup\": {:.3}\n}}\n",
                requests,
                workers,
                backend_name,
                tuned_cycles.len(),
                tuned_total,
                fixed_total,
                per_layer.join(", "),
                tuned.sequential_makespan_cycles,
                tuned.pipelined_makespan_cycles,
                tuned.pipeline_speedup(),
            );
            std::fs::write(&path, json)?;
            println!("\nwrote bench snapshot to {path}");
        }
    }

    coord.shutdown();
    println!("\nconv OK — all {requests} requests bit-exact in both configurations");
    Ok(())
}
