//! Scalability study (paper §IV-C): regenerate Table VI, Table VII and
//! Fig 4 from the virtual implementation model, and show SPAR-2's
//! ratio-dependence vs PiCaSO's BRAM-linear scaling.
//!
//! ```bash
//! cargo run --release --example scalability
//! ```

use picaso::arch::PipelineConfig;
use picaso::device::table7_devices;
use picaso::report::paper;
use picaso::synth::{ImplModel, OverlayDesign};

fn main() {
    print!("{}", paper::table7());
    println!();
    print!("{}", paper::table6());
    println!();
    print!("{}", paper::fig4());

    // The §IV-C argument, made quantitative: SPAR-2's reachable fraction
    // of the device's PE capacity vs the LUT-to-BRAM ratio.
    println!("\n## SPAR-2 reach vs LUT-to-BRAM ratio (PiCaSO reaches 100% everywhere)");
    let mut rows: Vec<_> = table7_devices()
        .into_iter()
        .map(|dev| {
            let bench = ImplModel::max_array(OverlayDesign::Benchmark, dev);
            let picaso =
                ImplModel::max_array(OverlayDesign::PiCaSO(PipelineConfig::FullPipe), dev);
            let reach = bench.pes as f64 / dev.max_pes() as f64;
            (dev.lut_bram_ratio(), dev.id, reach, bench.limiter, picaso.pes)
        })
        .collect();
    rows.sort_by_key(|r| r.0);
    for (ratio, id, reach, limiter, picaso_pes) in rows {
        println!(
            "  {id:5} ratio {ratio:5}: SPAR-2 reaches {:5.1}% of PE capacity ({}), \
             PiCaSO {} PEs (100%)",
            reach * 100.0,
            limiter.as_str(),
            picaso_pes,
        );
    }
    println!("\nscalability OK");
}
