//! Batched inference serving through the coordinator, measured closed
//! loop — the deployment shape a PIM overlay would actually run behind.
//!
//! Two phases over the same workload (single-sample MLP-layer GEMMs with
//! one pinned weight matrix, the repeat-inference regime):
//!
//! 1. **seed path** — micro-batching disabled, weights re-shipped with
//!    every job: exactly the one-job-per-invocation behaviour of the
//!    original coordinator.
//! 2. **serving path** — micro-batching + a persistent session: same-key
//!    jobs coalesce into packed array rounds and the weight staging is
//!    precomputed once; swept across client counts for a
//!    latency/throughput curve.
//!
//! Every result is verified against the software reference
//! (`gemm_ref`) in both phases — the speedup is at equal correctness.
//!
//! ```bash
//! cargo run --release --example serve -- [jobs-per-phase] [workers]
//! ```

use picaso::compiler::{gemm_ref, GemmShape};
use picaso::coordinator::{
    BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, SessionId,
};
use picaso::metrics::MetricsSnapshot;
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

/// Closed-loop load: `clients` threads, each submitting one job and
/// waiting on its handle before the next. Returns the phase snapshot and
/// the number of incorrect/failed jobs.
fn run_phase(
    coord: &Arc<Coordinator>,
    clients: usize,
    jobs: usize,
    shape: GemmShape,
    weights: &Arc<Vec<i64>>,
    session: Option<SessionId>,
    id_base: u64,
) -> picaso::Result<(MetricsSnapshot, usize)> {
    coord.serving_metrics().reset_window();
    let mut threads = Vec::new();
    for c in 0..clients {
        let quota = jobs / clients + usize::from(c < jobs % clients);
        let coord = Arc::clone(coord);
        let weights = Arc::clone(weights);
        threads.push(std::thread::spawn(move || -> picaso::Result<usize> {
            let mut rng = Xoshiro256::seeded(id_base ^ (0xC11E47 + c as u64));
            let mut bad = 0;
            for j in 0..quota {
                let id = id_base + (c * 1_000_000 + j) as u64;
                let mut a = vec![0i64; shape.m * shape.k];
                rng.fill_signed(&mut a, 8);
                let expect = gemm_ref(shape, &a, &weights);
                let handle = match session {
                    Some(sid) => coord.submit_session(id, sid, a)?,
                    None => coord.submit_job(Job {
                        id,
                        kind: JobKind::Gemm {
                            shape,
                            width: 8,
                            a,
                            b: weights.as_ref().clone(),
                        },
                    })?,
                };
                let r = handle.wait();
                if r.error.is_some() || r.output != expect {
                    bad += 1;
                }
            }
            Ok(bad)
        }));
    }
    let mut bad = 0;
    for t in threads {
        bad += t
            .join()
            .map_err(|_| picaso::Error::Runtime("client thread panicked".into()))??;
    }
    Ok((coord.metrics_snapshot(), bad))
}

fn main() -> picaso::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let jobs: usize = argv.first().and_then(|s| s.parse().ok()).unwrap_or(96);
    let workers: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let geom = ArrayGeometry::new(8, 4);
    // Single-sample inference against one pinned layer: 10 outputs per
    // job on an 8-row region — the ragged-round case micro-batching
    // packs away.
    let shape = GemmShape { m: 1, k: 64, n: 10 };
    println!(
        "serving {jobs} jobs/phase on {workers} workers, each an {}x{}-block PiCaSO-F region \
         ({} PEs); workload: {}x{}x{} int8 GEMM, pinned weights",
        geom.rows,
        geom.cols,
        geom.pes(),
        shape.m,
        shape.k,
        shape.n,
    );

    let mut rng = Xoshiro256::seeded(0x5E12);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let weights = Arc::new(weights);

    // ---------------------------------------------------- phase 1: seed
    // Saturating load (2 clients per worker) so both phases are compared
    // at the same offered concurrency.
    let load = 2 * workers.max(1);
    let seed_coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })?);
    let (seed_snap, seed_bad) = run_phase(&seed_coord, load, jobs, shape, &weights, None, 0)?;
    assert_eq!(seed_bad, 0, "seed path must verify against gemm_ref");
    if let Ok(c) = Arc::try_unwrap(seed_coord) {
        c.shutdown();
    }
    println!("\n--- seed path (no batching, per-job weights, {load} clients) ---");
    println!("{}", seed_snap.render());

    // ------------------------------------- phase 2: batched + session
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_micros(200) },
        ..Default::default()
    })?);
    let sid = coord.open_session(shape, 8, weights.as_ref().clone())?;

    println!("\n--- serving path (micro-batch ≤8 / 200us, session weights) ---");
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>10} {:>11}",
        "clients", "jobs/s", "p50 us", "p95 us", "p99 us", "mean batch"
    );
    let mut saturated: Option<MetricsSnapshot> = None;
    for (phase, clients) in [1usize, 2, workers.max(1), load].into_iter().enumerate() {
        let (snap, bad) = run_phase(
            &coord,
            clients,
            jobs,
            shape,
            &weights,
            Some(sid),
            (phase as u64 + 1) * 100_000_000,
        )?;
        assert_eq!(bad, 0, "serving path must verify against gemm_ref");
        println!(
            "{:>8} {:>12.1} {:>10.0} {:>10.0} {:>10.0} {:>11.2}",
            clients,
            snap.jobs_per_sec(),
            snap.total.p50,
            snap.total.p95,
            snap.total.p99,
            snap.mean_batch,
        );
        if clients == load {
            saturated = Some(snap);
        }
    }
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }

    // ------------------------------------------------------- comparison
    let batched = saturated.expect("saturated point measured");
    let speedup = if seed_snap.jobs_per_sec() > 0.0 {
        batched.jobs_per_sec() / seed_snap.jobs_per_sec()
    } else {
        0.0
    };
    println!(
        "\nat {load} clients: {:.1} jobs/s batched+session vs {:.1} jobs/s seed path \
         => {speedup:.2}x throughput (all outputs == gemm_ref in both phases)",
        batched.jobs_per_sec(),
        seed_snap.jobs_per_sec(),
    );
    println!(
        "simulated PE-cycles/job: seed {} vs batched {} (round packing)",
        if seed_snap.jobs > 0 { seed_snap.pim_cycles / seed_snap.jobs } else { 0 },
        if batched.jobs > 0 { batched.pim_cycles / batched.jobs } else { 0 },
    );
    println!("\nserve OK");
    Ok(())
}
