//! Batched inference serving through the coordinator, measured closed
//! loop — the deployment shape a PIM overlay would actually run behind.
//!
//! Two phases over the same workload (single-sample MLP-layer GEMMs with
//! one pinned weight matrix, the repeat-inference regime):
//!
//! 1. **seed path** — micro-batching disabled, weights re-shipped with
//!    every job: exactly the one-job-per-invocation behaviour of the
//!    original coordinator.
//! 2. **serving path** — micro-batching + a persistent session: same-key
//!    jobs coalesce into packed array rounds and the weight staging is
//!    precomputed once; swept across client counts for a
//!    latency/throughput curve.
//!
//! Every result is verified against the software reference
//! (`gemm_ref`) in both phases — the speedup is at equal correctness.
//!
//! The third argument picks the execution backend: a single design name
//! (`picaso`, `spar2`, `ccb`, `comefa-d`, `comefa-a`, `a-mod`, `d-mod`)
//! runs a homogeneous pool; `mixed` splits the pool into overlay +
//! CoMeFa-A regions, tags jobs to alternate classes, and reports the
//! per-backend throughput/latency comparison (the paper's Fig 6 /
//! Table V numbers under live load).
//!
//! A final **resilience phase** poisons one region with a
//! [`FaultInjector`] and serves sharded (ad-hoc and session-backed)
//! jobs through it: failure-domain retry must absorb every injected
//! fault bit-exactly, and a zero-deadline job must shed instead of
//! executing.
//!
//! ```bash
//! cargo run --release --example serve -- [jobs-per-phase] [workers] [backend] [--trace=<p>]
//! ```
//!
//! `--trace=<path>` attaches a span journal to the serving and chaos
//! phases and writes it as Chrome trace-event JSON — load it in
//! Perfetto or summarize it with `picaso trace <path>`.
//!
//! Set `SERVE_BENCH_JSON=<path>` to also write the headline numbers
//! (p50/p95 queue + end-to-end latency, throughput, retry/shed counts)
//! as a JSON object — the per-PR perf trajectory tracked by `ci.sh`'s
//! bench-smoke step.

use picaso::arch::CustomDesign;
use picaso::compiler::{gemm_ref, GemmShape};
use picaso::coordinator::{
    BackendHook, BatchPolicy, Coordinator, CoordinatorConfig, Job, JobKind, RegionSpec, SessionId,
};
use picaso::metrics::MetricsSnapshot;
use picaso::prelude::*;
use picaso::util::Xoshiro256;
use std::sync::Arc;
use std::time::Duration;

/// Closed-loop load: `clients` threads, each submitting one job and
/// waiting on its handle before the next. Returns the phase snapshot and
/// the number of incorrect/failed jobs.
fn run_phase(
    coord: &Arc<Coordinator>,
    clients: usize,
    jobs: usize,
    shape: GemmShape,
    weights: &Arc<Vec<i64>>,
    session: Option<SessionId>,
    tags: &Arc<Vec<Option<BackendClass>>>,
    id_base: u64,
) -> picaso::Result<(MetricsSnapshot, usize)> {
    coord.serving_metrics().reset_window();
    let mut threads = Vec::new();
    for c in 0..clients {
        let quota = jobs / clients + usize::from(c < jobs % clients);
        let coord = Arc::clone(coord);
        let weights = Arc::clone(weights);
        let tags = Arc::clone(tags);
        threads.push(std::thread::spawn(move || -> picaso::Result<usize> {
            let mut rng = Xoshiro256::seeded(id_base ^ (0xC11E47 + c as u64));
            let mut bad = 0;
            for j in 0..quota {
                let id = id_base + (c * 1_000_000 + j) as u64;
                let mut a = vec![0i64; shape.m * shape.k];
                rng.fill_signed(&mut a, 8);
                let expect = gemm_ref(shape, &a, &weights);
                // In mixed mode, alternate the backend tag so every
                // region kind serves an equal share of the load.
                let tag = tags[j % tags.len()];
                let kind = match session {
                    Some(sid) => JobKind::SessionGemm { session: sid, a: a.into() },
                    None => JobKind::Gemm {
                        shape,
                        width: 8,
                        a,
                        b: weights.as_ref().clone(),
                    },
                };
                let mut job = Job::new(id, kind);
                job.backend = tag;
                let handle = coord.submit_job(job)?;
                let r = handle.wait();
                if r.error.is_some() || r.output != expect {
                    bad += 1;
                }
            }
            Ok(bad)
        }));
    }
    let mut bad = 0;
    for t in threads {
        bad += t
            .join()
            .map_err(|_| picaso::Error::Runtime("client thread panicked".into()))??;
    }
    Ok((coord.metrics_snapshot(), bad))
}

fn main() -> picaso::Result<()> {
    // `--trace=<path>` can appear anywhere; the remaining tokens are the
    // positional [jobs] [workers] [backend].
    let (trace_path, argv): (Option<String>, Vec<String>) = {
        let mut trace = None;
        let mut rest = Vec::new();
        for tok in std::env::args().skip(1) {
            match tok.strip_prefix("--trace=") {
                Some(p) => trace = Some(p.to_string()),
                None => rest.push(tok),
            }
        }
        (trace, rest)
    };
    let jobs: usize = argv.first().and_then(|s| s.parse().ok()).unwrap_or(96);
    let workers: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let backend_name: String = argv.get(2).cloned().unwrap_or_else(|| "picaso".into());
    // Sized for the largest pool of the run (the chaos phase uses at
    // least two regions).
    let tracer = trace_path.as_ref().map(|_| Arc::new(Tracer::new(workers.max(2))));

    // Backend selection: homogeneous pool (same names/aliases as the
    // CLI's --backend, via the shared parser), or the mixed
    // overlay+CoMeFa-A comparison with per-class job tagging.
    let (kind, regions, tags): (ArchKind, Vec<RegionSpec>, Vec<Option<BackendClass>>) =
        if backend_name == "mixed" {
            (
                ArchKind::PICASO_F,
                RegionSpec::mixed_pool(workers),
                vec![
                    Some(BackendClass::Overlay),
                    Some(BackendClass::Custom(CustomDesign::CoMeFaA)),
                ],
            )
        } else {
            (picaso::cli::parse_backend(&backend_name)?, Vec::new(), vec![None])
        };
    let tags = Arc::new(tags);

    let geom = ArrayGeometry::new(8, 4);
    // Single-sample inference against one pinned layer: 10 outputs per
    // job on an 8-row region — the ragged-round case micro-batching
    // packs away.
    let shape = GemmShape { m: 1, k: 64, n: 10 };
    println!(
        "serving {jobs} jobs/phase on {workers} {backend_name} workers, each an {}x{}-block \
         region ({} PEs); workload: {}x{}x{} int8 GEMM, pinned weights",
        geom.rows,
        geom.cols,
        geom.pes(),
        shape.m,
        shape.k,
        shape.n,
    );

    let mut rng = Xoshiro256::seeded(0x5E12);
    let mut weights = vec![0i64; shape.k * shape.n];
    rng.fill_signed(&mut weights, 8);
    let weights = Arc::new(weights);

    // ---------------------------------------------------- phase 1: seed
    // Saturating load (2 clients per worker) so both phases are compared
    // at the same offered concurrency.
    let load = 2 * workers.max(1);
    let seed_coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        kind,
        regions: regions.clone(),
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })?);
    let (seed_snap, seed_bad) =
        run_phase(&seed_coord, load, jobs, shape, &weights, None, &tags, 0)?;
    assert_eq!(seed_bad, 0, "seed path must verify against gemm_ref");
    if let Ok(c) = Arc::try_unwrap(seed_coord) {
        c.shutdown();
    }
    println!("\n--- seed path (no batching, per-job weights, {load} clients) ---");
    println!("{}", seed_snap.render());

    // ------------------------------------- phase 2: batched + session
    let coord = Arc::new(Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        kind,
        regions: regions.clone(),
        batch: BatchPolicy::Fixed { max_batch: 8, max_wait: Duration::from_micros(200) },
        trace: tracer.clone(),
        ..Default::default()
    })?);
    let sid = coord.open_session(shape, 8, weights.as_ref().clone())?;

    println!("\n--- serving path (micro-batch ≤8 / 200us, session weights) ---");
    println!(
        "{:>8} {:>12} {:>10} {:>10} {:>10} {:>11}",
        "clients", "jobs/s", "p50 us", "p95 us", "p99 us", "mean batch"
    );
    let mut saturated: Option<MetricsSnapshot> = None;
    for (phase, clients) in [1usize, 2, workers.max(1), load].into_iter().enumerate() {
        let (snap, bad) = run_phase(
            &coord,
            clients,
            jobs,
            shape,
            &weights,
            Some(sid),
            &tags,
            (phase as u64 + 1) * 100_000_000,
        )?;
        assert_eq!(bad, 0, "serving path must verify against gemm_ref");
        println!(
            "{:>8} {:>12.1} {:>10.0} {:>10.0} {:>10.0} {:>11.2}",
            clients,
            snap.jobs_per_sec(),
            snap.total.p50,
            snap.total.p95,
            snap.total.p99,
            snap.mean_batch,
        );
        if clients == load {
            saturated = Some(snap);
        }
    }
    if let Ok(c) = Arc::try_unwrap(coord) {
        c.shutdown();
    }

    // ------------------------------------------------------- comparison
    let batched = saturated.expect("saturated point measured");
    let speedup = if seed_snap.jobs_per_sec() > 0.0 {
        batched.jobs_per_sec() / seed_snap.jobs_per_sec()
    } else {
        0.0
    };
    println!(
        "\nat {load} clients: {:.1} jobs/s batched+session vs {:.1} jobs/s seed path \
         => {speedup:.2}x throughput (all outputs == gemm_ref in both phases)",
        batched.jobs_per_sec(),
        seed_snap.jobs_per_sec(),
    );
    println!(
        "simulated PE-cycles/job: seed {} vs batched {} (round packing)",
        if seed_snap.jobs > 0 { seed_snap.pim_cycles / seed_snap.jobs } else { 0 },
        if batched.jobs > 0 { batched.pim_cycles / batched.jobs } else { 0 },
    );

    // Per-backend comparison at the saturated point — the Fig 6 /
    // Table V headline: throughput and tail latency per design class.
    if !batched.per_backend.is_empty() {
        println!("\n--- per-backend comparison at {load} clients ---");
        for b in &batched.per_backend {
            println!(
                "  {:<10} {:>8.1} jobs/s  p50={:>6.0}us p95={:>6.0}us p99={:>6.0}us  \
                 cycles/job={}",
                b.backend.name(),
                b.jobs_per_sec(batched.elapsed_s),
                b.total.p50,
                b.total.p95,
                b.total.p99,
                if b.jobs > 0 { b.pim_cycles / b.jobs } else { 0 },
            );
        }
    }

    // ------------------------------------ phase 3: scatter–gather shard
    // One large GEMM scattered across every region: the paper's
    // multi-block scaling applied to a single logical job instead of a
    // stream of independent ones. Unsharded, the job serializes on one
    // region while the rest idle; sharded `auto`, every compatible
    // region executes one output-column slice concurrently and the
    // handle gathers the partial results (bit-exact in both cases).
    let big = GemmShape { m: 8, k: 64, n: 6 * workers.max(1) };
    let mut a = vec![0i64; big.m * big.k];
    let mut b = vec![0i64; big.k * big.n];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect = gemm_ref(big, &a, &b);
    let coord = Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        kind,
        regions: regions.clone(),
        batch: BatchPolicy::disabled(),
        ..Default::default()
    })?;
    let solo = coord
        .submit_job(Job::new(0, JobKind::Gemm { shape: big, width: 8, a: a.clone(), b: b.clone() }))?
        .wait();
    assert!(solo.error.is_none(), "unsharded large GEMM failed: {:?}", solo.error);
    assert_eq!(solo.output, expect, "unsharded output must match gemm_ref");
    let sharded = coord
        .submit_job(
            Job::new(1, JobKind::Gemm { shape: big, width: 8, a, b })
                .with_shards(ShardPolicy::Auto),
        )?
        .wait();
    assert!(sharded.error.is_none(), "sharded large GEMM failed: {:?}", sharded.error);
    assert_eq!(sharded.output, expect, "gathered output must match gemm_ref");
    println!(
        "\n--- sharded scatter–gather: one {}x{}x{} GEMM across {} regions ---",
        big.m,
        big.k,
        big.n,
        coord.worker_kinds().len(),
    );
    println!(
        "  unsharded: 1 region,  {} instructions on the critical path",
        solo.stats.instructions,
    );
    println!(
        "  sharded:   {} shards, ~{} instructions per region (total {} — same work, \
         ~{}x shorter critical path)",
        sharded.shards,
        sharded.stats.instructions / sharded.shards.max(1) as u64,
        sharded.stats.instructions,
        sharded.shards,
    );
    coord.shutdown();

    // --------------------------------------- phase 4: resilience drill
    // Poison one region outright (every execute on it fails) and serve
    // sharded jobs — ad-hoc and session-backed — through the degraded
    // pool: failure-domain retry re-queues each failing shard onto a
    // healthy region, so every result stays bit-exact and the only
    // visible symptom is the retry counter. A zero-deadline job is shed
    // at pop time instead of wasting an array invocation.
    // The chaos pool mirrors the pool under test (mixed mode keeps its
    // overlay + CoMeFa-A regions); `regions` being non-empty overrides
    // `workers`, and a homogeneous pool gets at least two regions so
    // retry always has a healthy domain.
    let chaos = Arc::new(Coordinator::new(CoordinatorConfig {
        workers: workers.max(2), // retry needs at least one healthy domain
        geom,
        kind,
        regions,
        batch: BatchPolicy::disabled(),
        backend_hook: Some(BackendHook(Arc::new(|widx, inner| {
            if widx == 0 {
                Box::new(FaultInjector::new(inner, FaultPlan::Poisoned))
            } else {
                inner
            }
        }))),
        trace: tracer.clone(),
        ..Default::default()
    })?);
    chaos.serving_metrics().reset_window();
    let chaos_shape = GemmShape { m: 2, k: 64, n: 2 * workers.max(2) };
    let mut cw = vec![0i64; chaos_shape.k * chaos_shape.n];
    rng.fill_signed(&mut cw, 8);
    let chaos_sid = chaos.open_session(chaos_shape, 8, cw.clone())?;
    let chaos_jobs = 12usize;
    let mut chaos_bad = 0usize;
    for i in 0..chaos_jobs {
        let mut a = vec![0i64; chaos_shape.m * chaos_shape.k];
        rng.fill_signed(&mut a, 8);
        let expect = gemm_ref(chaos_shape, &a, &cw);
        // Alternate ad-hoc and session-backed sharded jobs.
        let kind = if i % 2 == 0 {
            JobKind::Gemm { shape: chaos_shape, width: 8, a, b: cw.clone() }
        } else {
            JobKind::SessionGemm { session: chaos_sid, a: a.into() }
        };
        let r = chaos
            .submit_job(Job::new(i as u64, kind).with_shards(ShardPolicy::Auto))?
            .wait();
        if r.error.is_some() || r.output != expect {
            chaos_bad += 1;
        }
    }
    // Deadline shedding: a job that expired in the queue is dropped at
    // pop time with a shed result, not executed.
    let shed_r = chaos
        .submit_job(
            Job::new(999, JobKind::SessionGemm { session: chaos_sid, a: vec![0; chaos_shape.m * chaos_shape.k].into() })
                .with_deadline_us(0.0),
        )?
        .wait();
    assert!(shed_r.shed, "zero-deadline job must shed, got {:?}", shed_r.error);
    let chaos_snap = chaos.metrics_snapshot();
    if let Ok(c) = Arc::try_unwrap(chaos) {
        c.shutdown();
    }
    assert_eq!(chaos_bad, 0, "retry must absorb the poisoned region bit-exactly");
    println!(
        "\n--- resilience: region 0 poisoned, {chaos_jobs} sharded jobs (ad-hoc + session) ---"
    );
    println!(
        "  all outputs == gemm_ref; retries absorbed: {}, deadline sheds: {}, \
         region quarantines: {}",
        chaos_snap.retries, chaos_snap.sheds, chaos_snap.quarantines,
    );

    // ------------------------------------------------ bench JSON (CI)
    if let Ok(path) = std::env::var("SERVE_BENCH_JSON") {
        if !path.is_empty() {
            let json = format!(
                "{{\n  \"jobs_per_phase\": {},\n  \"workers\": {},\n  \"backend\": \"{}\",\n  \
                 \"jobs_per_sec\": {:.3},\n  \"speedup_vs_seed\": {:.3},\n  \
                 \"queue_p50_us\": {:.3},\n  \"queue_p95_us\": {:.3},\n  \
                 \"wall_p50_us\": {:.3},\n  \"wall_p95_us\": {:.3},\n  \
                 \"pim_cycles_per_job\": {},\n  \"retries\": {},\n  \"sheds\": {}\n}}\n",
                jobs,
                workers,
                backend_name,
                batched.jobs_per_sec(),
                speedup,
                batched.queue_wait.p50,
                batched.queue_wait.p95,
                batched.total.p50,
                batched.total.p95,
                if batched.jobs > 0 { batched.pim_cycles / batched.jobs } else { 0 },
                chaos_snap.retries,
                chaos_snap.sheds,
            );
            std::fs::write(&path, json)?;
            println!("\nwrote bench snapshot to {path}");
        }
    }

    // ------------------------------------------------ trace export
    if let (Some(tr), Some(path)) = (&tracer, &trace_path) {
        TraceSink::write(tr, std::path::Path::new(path))?;
        println!(
            "wrote {} spans (dropped {}) to {path} — summarize with `picaso trace {path}`",
            tr.events().len(),
            tr.dropped(),
        );
    }

    println!("\nserve OK");
    Ok(())
}
