//! Batched inference serving through the coordinator: a stream of GEMM
//! jobs (MLP layers) dispatched across worker regions, with latency
//! percentiles and throughput — the deployment shape a PIM overlay would
//! actually run behind.
//!
//! ```bash
//! cargo run --release --example serve -- [jobs] [workers]
//! ```

use picaso::compiler::{gemm_ref, GemmShape};
use picaso::coordinator::{Coordinator, CoordinatorConfig, Job, JobKind};
use picaso::prelude::*;
use picaso::util::Xoshiro256;

fn main() -> picaso::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let jobs: usize = argv.first().and_then(|s| s.parse().ok()).unwrap_or(64);
    let workers: usize = argv.get(1).and_then(|s| s.parse().ok()).unwrap_or(4);

    let geom = ArrayGeometry::new(8, 4);
    println!(
        "serving {jobs} jobs on {workers} workers, each a {}x{}-block PiCaSO-F region ({} PEs)",
        geom.rows,
        geom.cols,
        geom.pes()
    );
    let mut coord = Coordinator::new(CoordinatorConfig {
        workers,
        geom,
        ..Default::default()
    })?;

    // A mixed stream of MLP-layer shapes (the paper's target workloads).
    let shapes = [
        GemmShape { m: 16, k: 64, n: 32 },
        GemmShape { m: 16, k: 32, n: 10 },
        GemmShape { m: 8, k: 128, n: 16 },
    ];
    let mut rng = Xoshiro256::seeded(0x5E12);
    let mut batch = Vec::new();
    let mut expected = Vec::new();
    for id in 0..jobs as u64 {
        let shape = shapes[id as usize % shapes.len()];
        let mut a = vec![0i64; shape.m * shape.k];
        let mut b = vec![0i64; shape.k * shape.n];
        rng.fill_signed(&mut a, 8);
        rng.fill_signed(&mut b, 8);
        expected.push(gemm_ref(shape, &a, &b));
        batch.push(Job { id, kind: JobKind::Gemm { shape, width: 8, a, b } });
    }

    let (results, mut metrics) = coord.run_batch(batch)?;

    // Verify every result against software.
    let mut verified = 0;
    for r in &results {
        assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
        assert_eq!(r.output, expected[r.id as usize], "job {}", r.id);
        verified += 1;
    }
    // Worker balance.
    let mut per_worker = std::collections::HashMap::new();
    for r in &results {
        *per_worker.entry(r.worker).or_insert(0usize) += 1;
    }
    coord.shutdown();

    println!("\nall {verified} results verified against software GEMM");
    println!("worker balance: {per_worker:?}");
    println!("{}", metrics.summary());
    println!(
        "latency p50/p90/p99: {:.0} / {:.0} / {:.0} us",
        metrics.latency_us.quantile(0.50).unwrap_or(0.0),
        metrics.latency_us.quantile(0.90).unwrap_or(0.0),
        metrics.latency_us.quantile(0.99).unwrap_or(0.0),
    );
    println!(
        "simulated PE-cycles/s: {}",
        picaso::util::fmt_rate(metrics.sim_cycles_per_sec(), "cyc")
    );
    println!("\nserve OK");
    Ok(())
}
