//! Quickstart: build a PiCaSO array, run a multiply-accumulate, verify it
//! against software, and cross-check the cycle count against the paper's
//! Table V algebra.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use picaso::compiler::{BUF_A, BUF_B, BUF_OUT};
use picaso::prelude::*;
use picaso::util::Xoshiro256;

fn main() -> picaso::Result<()> {
    // An 8-block row: q = 128 PEs, the Table V test configuration.
    let geom = ArrayGeometry::new(1, 8);
    let mut array = PimArray::new(geom, PipelineConfig::FullPipe);
    println!(
        "PiCaSO-F array: {} blocks x 16 PEs = {} PEs (q = {})",
        geom.rows * geom.cols,
        geom.pes(),
        geom.row_lanes()
    );

    // Random int8 operands, one pair per PE.
    let mut rng = Xoshiro256::seeded(2023);
    let mut a = vec![0i64; geom.pes()];
    let mut b = vec![0i64; geom.pes()];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    array.set_buffer(BUF_A, a.clone());
    array.set_buffer(BUF_B, b.clone());

    // Multiply every pair, then reduce the row with the OpMux folds and
    // the binary-hopping network.
    let program = MacProgram::elementwise_mul_then_accumulate(8, geom.row_lanes());
    println!("\nmicrocode:\n{}", picaso::isa::asm::format_program(&program));
    let stats = array.execute(&program)?;

    let expect: i64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
    let got = array.buffer(BUF_OUT).expect("stored")[0];
    assert_eq!(got, expect, "PIM result must match software");
    println!("dot product of {} int8 pairs = {got}  (software agrees)", geom.pes());

    // Cycle accounting vs the paper's closed forms.
    let model = ArchKind::PICASO_F.cycles();
    println!("\ncycles: {} total", stats.cycles);
    println!("  MULT       : {:5} (Table V: 2N^2+2N = {})", stats.breakdown.mult, model.mult(8));
    println!(
        "  Accumulate : {:5} (Table V @ q=128: {})",
        stats.breakdown.accumulate,
        model.accumulate(128, 16)
    );
    let f = 737e6; // U55 BRAM Fmax — PiCaSO-F runs at BRAM speed (§IV-A)
    println!(
        "  at 737 MHz (U55 BRAM Fmax): {}",
        picaso::util::fmt_ns(stats.time_ns(f))
    );

    // The headline Table V comparison: same reduction on SPAR-2.
    let spar2 = ArchKind::Spar2.cycles().accumulate(128, 32);
    let picaso = model.accumulate(128, 32);
    println!(
        "\nTable V (q=128, N=32): SPAR-2 {spar2} cycles vs PiCaSO-F {picaso} — {:.1}x faster",
        spar2 as f64 / picaso as f64
    );
    println!("\nquickstart OK");
    Ok(())
}
