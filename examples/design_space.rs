//! Overlay-or-overhaul design-space study (paper §V): regenerate Fig 5,
//! Fig 6, Fig 7 and Table VIII, then validate the analytic MAC numbers
//! against the *behavioural* simulators — the overlay array and the
//! custom-tile models compute the same dot products and their charged
//! cycles must equal the closed forms.
//!
//! ```bash
//! cargo run --release --example design_space
//! ```

use picaso::arch::{ArchKind, CustomDesign};
use picaso::compiler::{BUF_A, BUF_B};
use picaso::custom::CustomTile;
use picaso::isa::{Instruction, Microcode, RfAddr};
use picaso::prelude::*;
use picaso::report::paper;
use picaso::util::Xoshiro256;

fn main() -> picaso::Result<()> {
    print!("{}", paper::fig5());
    println!();
    print!("{}", paper::fig6());
    println!();
    print!("{}", paper::fig7());
    println!();
    print!("{}", paper::table8());

    // Behavioural cross-check: run the Fig 5 workload (16 MULTs + q=16
    // reduce, N=8) on every design's simulator and compare cycles with
    // the analytic model driving the figures.
    println!("\n## behavioural cross-check (16 parallel MACs, N=8, q=16)");
    let mut rng = Xoshiro256::seeded(55);
    let mut a = vec![0i64; 16];
    let mut b = vec![0i64; 16];
    rng.fill_signed(&mut a, 8);
    rng.fill_signed(&mut b, 8);
    let expect: i64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();

    // Overlay (PiCaSO-F): one block row.
    let geom = ArrayGeometry::new(1, 1);
    let mut arr = PimArray::new(geom, PipelineConfig::FullPipe);
    arr.set_buffer(BUF_A, a.clone());
    arr.set_buffer(BUF_B, b.clone());
    let mut mc = Microcode::new("fig5-wl", 8);
    mc.push(Instruction::Load { dst: RfAddr(0), width: 8, buf: BUF_A });
    mc.push(Instruction::Load { dst: RfAddr(8), width: 8, buf: BUF_B });
    mc.push(Instruction::Mult { dst: RfAddr(16), mand: RfAddr(0), mier: RfAddr(8), width: 8 });
    mc.push(Instruction::Accumulate { dst: RfAddr(16), width: 8 });
    let stats = arr.execute(&mc)?;
    let picaso_cycles = stats.breakdown.mult + stats.breakdown.accumulate;
    let model = ArchKind::PICASO_F.cycles();
    assert_eq!(picaso_cycles, model.mult(8) + model.accumulate(16, 8));
    println!(
        "  PiCaSO-F : sim {picaso_cycles:4} cycles == analytic {} (result {})",
        model.mult(8) + model.accumulate(16, 8),
        arr.row_values(0, RfAddr(16), 8)[0],
    );

    // Custom tiles: same workload on the behavioural models.
    for design in CustomDesign::ALL {
        let mut tile = CustomTile::new(design);
        let (sum, tile_stats) = tile.mac_group(&a, &b, 8, 16)?;
        assert_eq!(sum, expect, "{design:?} computes the right dot product");
        let m = ArchKind::Custom(design).cycles();
        assert_eq!(tile_stats.breakdown.mult, m.mult(8), "{design:?}");
        assert_eq!(tile_stats.breakdown.accumulate, m.accumulate(16, 16), "{design:?}");
        println!(
            "  {:<8} : sim {:4} cycles == analytic {} (result {sum})",
            design.name(),
            tile_stats.cycles,
            m.mult(8) + m.accumulate(16, 16),
        );
    }

    println!("\ndesign_space OK — every figure backed by a behavioural model");
    Ok(())
}
