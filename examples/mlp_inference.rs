//! End-to-end driver: quantized MLP inference on the simulated PiCaSO
//! overlay, golden-checked **bit-for-bit** against the AOT-compiled JAX
//! model executed through PJRT — all three layers of the stack composing:
//!
//!   L1 (Pallas bit-plane MAC) + L2 (JAX MLP) --aot.py--> artifacts/*.hlo.txt
//!   L3 (this binary): corner-turn -> PIM microcode -> cycle-accurate sim
//!                     -> XLA golden cross-check -> latency/throughput report
//!
//! Workload: batch of 16 synthetic samples through a 64→32→10 int8 MLP
//! (the MLP/RNN class the paper's introduction motivates: low operational
//! intensity, dominated by memory — exactly PIM's target).
//!
//! ```bash
//! make artifacts && cargo run --release --example mlp_inference
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use picaso::compiler::{execute_gemm, GemmShape, PimCompiler};
use picaso::coordinator::{Coordinator, CoordinatorConfig, Job, JobKind};
use picaso::prelude::*;
use picaso::runtime::{artifact, XlaRuntime, ARTIFACTS_DIR};
use picaso::util::Xoshiro256;
use std::time::Instant;

// Keep in sync with python/compile/model.py.
const IN: usize = 64;
const HIDDEN: usize = 32;
const OUT: usize = 10;
const BATCH: usize = 16;
const SHIFT: u32 = 7;

struct MlpParams {
    w1: Vec<i64>, // IN x HIDDEN
    b1: Vec<i64>,
    w2: Vec<i64>, // HIDDEN x OUT
    b2: Vec<i64>,
}

/// Matched-filter parameters: hidden unit `j < OUT` is the template
/// detector for class `j` (a hand-constructed classifier — the weights a
/// trained MLP would converge to on this synthetic task); remaining
/// hidden units carry small random weights to exercise full width.
fn synth_params(rng: &mut Xoshiro256) -> MlpParams {
    let mut w1 = vec![0i64; IN * HIDDEN];
    let mut w2 = vec![0i64; HIDDEN * OUT];
    let b1 = vec![0i64; HIDDEN];
    let b2 = vec![0i64; OUT];
    for j in 0..HIDDEN {
        for i in 0..IN {
            w1[i * HIDDEN + j] = if j < OUT {
                // matched filter for class j's template
                if (i + j * 7) % OUT == 0 { 4 } else { -1 }
            } else {
                rng.range_i64(-2, 2)
            };
        }
    }
    for j in 0..OUT {
        w2[j * OUT + j] = 8; // route detector j to logit j
    }
    MlpParams { w1, b1, w2, b2 }
}

/// Synthetic "digits": each sample is a noisy template of its class —
/// a tiny stand-in for the sensor workloads of SPAR-2's IoT setting.
fn synth_batch(rng: &mut Xoshiro256) -> (Vec<i64>, Vec<usize>) {
    let mut x = vec![0i64; BATCH * IN];
    let mut labels = vec![0usize; BATCH];
    for s in 0..BATCH {
        let class = s % OUT;
        labels[s] = class;
        for i in 0..IN {
            let template = if (i + class * 7) % OUT == 0 { 90 } else { -30 };
            let noise = rng.range_i64(-25, 25);
            x[s * IN + i] = (template + noise).clamp(-128, 127);
        }
    }
    (x, labels)
}

/// The integer MLP semantics (mirrors python/compile/model.py exactly).
fn mlp_postproc_layer1(acc: &[i64], b1: &[i64]) -> Vec<i64> {
    acc.iter()
        .enumerate()
        .map(|(idx, &v)| {
            let j = idx % HIDDEN;
            let z = (v + b1[j]).max(0) >> SHIFT;
            z.min(127)
        })
        .collect()
}

fn main() -> picaso::Result<()> {
    println!("=== PiCaSO end-to-end MLP inference ===\n");
    let mut rng = Xoshiro256::seeded(0xD161);
    let params = synth_params(&mut rng);
    let (x, labels) = synth_batch(&mut rng);

    // ---------------------------------------------------------------- L3
    // The PIM path: two GEMMs on the simulated overlay + integer postproc.
    let geom = ArrayGeometry::new(8, 4); // 8 rows x 64 lanes
    let mut array = PimArray::new(geom, PipelineConfig::FullPipe);
    let compiler = PimCompiler::new(geom);
    let plan1 = compiler.gemm(GemmShape { m: BATCH, k: IN, n: HIDDEN }, 8)?;
    let plan2 = compiler.gemm(GemmShape { m: BATCH, k: HIDDEN, n: OUT }, 8)?;

    let t0 = Instant::now();
    let (acc1, stats1) = execute_gemm(&mut array, &plan1, &x, &params.w1)?;
    let h = mlp_postproc_layer1(&acc1, &params.b1);
    let (acc2, stats2) = execute_gemm(&mut array, &plan2, &h, &params.w2)?;
    let logits_pim: Vec<i64> = acc2
        .iter()
        .enumerate()
        .map(|(idx, &v)| v + params.b2[idx % OUT])
        .collect();
    let wall = t0.elapsed();

    let cycles = stats1.cycles + stats2.cycles;
    let freq = 737e6; // PiCaSO-F at U55 BRAM Fmax
    let pim_time_s = cycles as f64 / freq;
    let macs = (BATCH * IN * HIDDEN + BATCH * HIDDEN * OUT) as f64;
    println!("PIM path (cycle-accurate sim, {}x{} blocks):", geom.rows, geom.cols);
    println!("  pim cycles        : {cycles}");
    println!("  modeled latency   : {} @ 737 MHz", picaso::util::fmt_ns(pim_time_s * 1e9));
    println!(
        "  modeled throughput: {} ({} samples/s)",
        picaso::util::fmt_rate(macs / pim_time_s, "MAC"),
        (BATCH as f64 / pim_time_s).round()
    );
    println!("  sim wall          : {wall:?}\n");

    // ---------------------------------------------------------------- XLA
    // Golden path: the AOT-compiled JAX MLP through PJRT.
    let mut rt = XlaRuntime::cpu(ARTIFACTS_DIR)?;
    if !rt.has_artifact(artifact::MLP) {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }
    rt.load(artifact::MLP)?;
    println!("XLA golden model loaded on {}", rt.platform());
    let f32v = |v: &[i64]| -> Vec<f32> { v.iter().map(|&x| x as f32).collect() };
    let t1 = Instant::now();
    let logits_xla = rt.run_f32(
        artifact::MLP,
        &[
            (f32v(&x), vec![BATCH, IN]),
            (f32v(&params.w1), vec![IN, HIDDEN]),
            (f32v(&params.b1), vec![HIDDEN]),
            (f32v(&params.w2), vec![HIDDEN, OUT]),
            (f32v(&params.b2), vec![OUT]),
        ],
    )?;
    let xla_wall = t1.elapsed();
    println!("  xla wall          : {xla_wall:?}\n");

    // ------------------------------------------------------------ verify
    let logits_xla_i: Vec<i64> = logits_xla.iter().map(|&v| v.round() as i64).collect();
    assert_eq!(
        logits_pim, logits_xla_i,
        "PIM and XLA golden logits must match bit-for-bit"
    );
    println!("golden check: PIM logits == XLA logits for all {} values ✔", logits_pim.len());

    let classify = |logits: &[i64]| -> Vec<usize> {
        (0..BATCH)
            .map(|s| {
                (0..OUT)
                    .max_by_key(|&c| logits[s * OUT + c])
                    .unwrap_or(0)
            })
            .collect()
    };
    let preds = classify(&logits_pim);
    let agree = preds.iter().zip(&labels).filter(|(p, l)| p == l).count();
    println!("classification accuracy: {agree}/{BATCH} on the synthetic template task\n");
    assert!(agree >= BATCH * 3 / 4, "matched-filter MLP should classify its templates");

    // ----------------------------------------------------- batch serving
    // Throughput under the coordinator: many batches across workers.
    let jobs = 32;
    let mut coord = Coordinator::new(CoordinatorConfig {
        workers: 4,
        geom,
        ..Default::default()
    })?;
    let mut batch_jobs = Vec::new();
    for id in 0..jobs as u64 {
        batch_jobs.push(Job::new(
            id,
            JobKind::Gemm {
                shape: GemmShape { m: BATCH, k: IN, n: HIDDEN },
                width: 8,
                a: x.clone(),
                b: params.w1.clone(),
            },
        ));
    }
    let (results, mut metrics) = coord.run_batch(batch_jobs)?;
    let failures = results.iter().filter(|r| r.error.is_some()).count();
    coord.shutdown();
    println!("serving: {}", metrics.summary());
    assert_eq!(failures, 0);

    println!("\nmlp_inference OK — record in EXPERIMENTS.md §End-to-end");
    Ok(())
}
